"""Cross-strategy / cross-executor differential fuzz harness.

"Toward Understanding Bugs in Vector Database Management Systems"
(arXiv 2506.02617) finds the dominant VDBMS bug class is cross-component
inconsistency — exactly what three scope strategies × five executor paths ×
DSM mutation risk here. This harness is the consistency net: a seeded random
op sequence (ingest / mkdir / move / merge / rmdir / delete / dsq /
dsq_batch / crash+recover) executes against all three strategies (PE-Online,
PE-Offline, TrieHI) and, at checkpoints, every executor path (flat loop,
flat batch, sharded batch, ivf device+loop, pg) — verified against a naive
pure-Python oracle and against each other:

* strategies must agree *exactly* with each other and with the oracle on
  every resolved scope (rmdir removal sets included);
* flat / sharded results must match the oracle's exact top-k (score parity,
  tie-tolerant id sets) and each other bit-for-bit;
* ivf's device path must match its per-query loop oracle, and every
  approximate result (ivf, pg) must stay inside the oracle scope with
  correctly-computed scores;
* crash+recover replays a journaled-but-unapplied op (BEGIN without COMMIT,
  i.e. a crash between journal append and mutation) and the recovered state
  must equal the oracle's post-op state.
"""
import os
import tempfile
from typing import Dict, List, Optional, Set, Tuple

import numpy as np
import pytest

from repro.core import DSM, STRATEGIES
from repro.core import paths as P
from repro.vectordb import DirectoryVectorDB, MaintenancePolicy

DIM = 16
K = 5
NPROBE = 4
EF = 48


# ------------------------------------------------------------------- oracle
class PyOracle:
    """Naive pure-Python model of DirectoryVectorDB's directory semantics:
    a flat {entry_id -> directory path} map plus a directory set, mutated by
    prefix rewriting. Deliberately structure-free — no tries, postings or
    bitmaps — so it cannot share a bug with any strategy."""

    def __init__(self):
        self.dirs: Set[Tuple[str, ...]] = {()}
        self.entries: Dict[int, Tuple[str, ...]] = {}
        self.vectors: Dict[int, np.ndarray] = {}

    def _add_dir(self, p: Tuple[str, ...]) -> None:
        for i in range(len(p) + 1):
            self.dirs.add(p[:i])

    def ingest(self, ids, vectors, paths) -> None:
        for eid, vec, path in zip(ids, vectors, paths):
            pt = P.parse(path)
            self._add_dir(pt)
            self.entries[int(eid)] = pt
            self.vectors[int(eid)] = np.asarray(vec, np.float32)

    def mkdir(self, path) -> None:
        self._add_dir(P.parse(path))

    def delete(self, eid: int) -> None:
        self.entries.pop(int(eid), None)

    @staticmethod
    def _under(d: Tuple[str, ...], p: Tuple[str, ...]) -> bool:
        return d[: len(p)] == p

    def _rekey(self, old: Tuple[str, ...], new: Tuple[str, ...]) -> None:
        self.dirs = {new + d[len(old):] if self._under(d, old) else d
                     for d in self.dirs}
        for eid, d in list(self.entries.items()):
            if self._under(d, old):
                self.entries[eid] = new + d[len(old):]

    def move(self, src, new_parent) -> None:
        s, npar = P.parse(src), P.parse(new_parent)
        self._add_dir(npar)
        self._rekey(s, npar + (s[-1],))

    def merge(self, src, dst) -> None:
        self._rekey(P.parse(src), P.parse(dst))

    def remove(self, path) -> Set[int]:
        p = P.parse(path)
        removed = {eid for eid, d in self.entries.items()
                   if self._under(d, p)}
        for eid in removed:
            del self.entries[eid]
        self.dirs = {d for d in self.dirs if not self._under(d, p)}
        return removed

    def resolve(self, path, recursive=True, exclude=()) -> Set[int]:
        p = P.parse(path)
        if recursive:
            ids = {eid for eid, d in self.entries.items()
                   if self._under(d, p)}
        else:
            ids = {eid for eid, d in self.entries.items() if d == p}
        for ex in exclude:
            e = P.parse(ex)
            ids -= {eid for eid, d in self.entries.items()
                    if self._under(d, e)}
        return ids

    def scores(self, q: np.ndarray, ids) -> Dict[int, float]:
        return {eid: float(self.vectors[eid] @ q.astype(np.float32))
                for eid in ids}

    def topk(self, q: np.ndarray, scope: Set[int], k: int
             ) -> List[Tuple[int, float]]:
        sc = self.scores(q, scope)
        return sorted(sc.items(), key=lambda t: (-t[1], t[0]))[:k]


# ---------------------------------------------------------------- generator
class FuzzState:
    def __init__(self, seed: int, tmpdir: str):
        self.rng = np.random.default_rng(seed)
        self.oracle = PyOracle()
        self.dbs: Dict[str, DirectoryVectorDB] = {}
        for strat in STRATEGIES:
            self.dbs[strat] = DirectoryVectorDB(
                dim=DIM, scope_strategy=strat,
                journal_path=os.path.join(tmpdir, f"journal.{strat}"))
        self.alive: List[int] = []
        # one shared policy object so db.maintenance() reuses its manager;
        # low thresholds make every op kind reachable at fuzz scale
        self._maint_policy = MaintenancePolicy(
            tombstone_min=8, tombstone_fraction=0.05,
            pad_waste_min=32, pad_waste_fraction=0.10,
            repair_deletes=4, n_iters=2, sample=64)

    # -- helpers ----------------------------------------------------------
    def _dirs(self, non_root=False) -> List[Tuple[str, ...]]:
        ds = sorted(self.oracle.dirs)
        return [d for d in ds if d] if non_root else ds

    def _pick_dir(self, non_root=False) -> Optional[Tuple[str, ...]]:
        ds = self._dirs(non_root)
        if not ds:
            return None
        return ds[int(self.rng.integers(len(ds)))]

    # -- ops --------------------------------------------------------------
    def op_ingest(self, n: Optional[int] = None) -> None:
        n = n or int(self.rng.integers(1, 9))
        dirs = self._dirs()
        paths = [P.to_str(dirs[int(self.rng.integers(len(dirs)))])
                 for _ in range(n)]
        vecs = self.rng.normal(size=(n, DIM)).astype(np.float32)
        ids = None
        for db in self.dbs.values():
            got = db.ingest(vecs, paths)
            assert ids is None or np.array_equal(ids, got)
            ids = got
        self.oracle.ingest(ids, vecs, paths)
        self.alive.extend(int(i) for i in ids)

    def op_mkdir(self) -> None:
        parent = self._pick_dir()
        name = f"n{int(self.rng.integers(1 << 30))}"
        path = P.to_str(parent + (name,))
        for db in self.dbs.values():
            db.mkdir(path)
        self.oracle.mkdir(path)

    def op_move(self) -> bool:
        for _ in range(20):
            src = self._pick_dir(non_root=True)
            npar = self._pick_dir()
            if src is None or npar is None:
                return False
            if P.is_ancestor(src, npar) or npar[: len(src)] == src:
                continue
            if npar + (src[-1],) in self.oracle.dirs:
                continue             # dest name conflict: move() rejects
            if npar == src[:-1]:
                continue             # no-op move to own parent
            for db in self.dbs.values():
                db.move(P.to_str(src), P.to_str(npar))
            self.oracle.move(P.to_str(src), P.to_str(npar))
            return True
        return False

    def op_merge(self) -> bool:
        for _ in range(20):
            src = self._pick_dir(non_root=True)
            dst = self._pick_dir(non_root=True)
            if src is None or dst is None:
                return False
            if src == dst or self.oracle._under(src, dst) \
                    or self.oracle._under(dst, src):
                continue
            for db in self.dbs.values():
                db.merge(P.to_str(src), P.to_str(dst))
            self.oracle.merge(P.to_str(src), P.to_str(dst))
            return True
        return False

    def op_rmdir(self) -> bool:
        src = self._pick_dir(non_root=True)
        if src is None:
            return False
        removed_sets = []
        for db in self.dbs.values():
            removed_sets.append(
                {int(i) for i in db.rmdir(P.to_str(src))})
        want = self.oracle.remove(P.to_str(src))
        for got in removed_sets:
            assert got == want, (got, want)
        self.alive = [i for i in self.alive if i not in want]
        return True

    def op_delete(self) -> bool:
        if not self.alive:
            return False
        eid = self.alive.pop(int(self.rng.integers(len(self.alive))))
        for db in self.dbs.values():
            db.delete(eid)
        self.oracle.delete(eid)
        return True

    def op_maintenance(self) -> bool:
        """Online maintenance differential: every strategy DB saw identical
        churn, so due() and each journaled op (PG repair, compaction,
        seeded repartition) must run identically on all three — and the
        compaction's order-preserving id remap must rekey the oracle to
        exactly the ids the DBs now return."""
        first = next(iter(self.dbs.values()))
        if not first.executors:
            return False                   # pre-build_ann: nothing to repair
        n = len(first.store)
        alive_b = first.store.alive_bool()
        ran: Optional[List[str]] = None
        for strat, db in self.dbs.items():
            mgr = db.maintenance(policy=self._maint_policy)
            kinds = [r["kind"] for r in mgr.run_all()]
            assert ran is None or kinds == ran, (strat, kinds, ran)
            assert mgr.stats()["journal_pending"] == 0, strat
            ran = kinds
        if ran and "maint_compact" in ran:
            # ids are store rows and compaction slides alive rows down in
            # order, so the mapping is computable from the pre-op alive set
            alive_rows = (np.nonzero(alive_b)[0] if alive_b is not None
                          else np.arange(n))
            mapping = np.full(n, -1, np.int64)
            mapping[alive_rows] = np.arange(len(alive_rows))
            self.oracle.entries = {int(mapping[e]): d for e, d
                                   in self.oracle.entries.items()}
            self.oracle.vectors = {int(mapping[e]): v for e, v
                                   in self.oracle.vectors.items()}
            self.alive = [int(mapping[i]) for i in self.alive]
            assert all(i >= 0 for i in self.alive)
        return bool(ran)

    def op_crash_recover(self) -> None:
        """recover() on a healthy journal must replay nothing and leave
        every invariant intact."""
        for db in self.dbs.values():
            replayed = db.recover()
            assert all(not ops for ops in replayed.values()), replayed
            db.check_invariants()

    def random_scope(self) -> Tuple[str, bool, List[str]]:
        anchor = self._pick_dir() or ()
        recursive = bool(self.rng.random() < 0.8)
        exclude: List[str] = []
        if recursive and self.rng.random() < 0.3:
            subs = [d for d in self._dirs(non_root=True)
                    if self.oracle._under(d, anchor) and d != anchor]
            if subs:
                exclude = [P.to_str(subs[int(self.rng.integers(len(subs)))])]
        return P.to_str(anchor), recursive, exclude

    # -- checks -----------------------------------------------------------
    def check_dsq(self) -> None:
        q = self.rng.normal(size=DIM).astype(np.float32)
        path, rec, exc = self.random_scope()
        scope = self.oracle.resolve(path, rec, exc)
        want = self.oracle.topk(q, scope, K)
        per_exec: Dict[str, list] = {}
        for strat, db in self.dbs.items():
            for name, params in (("flat", {}), ("sharded", {}),
                                 ("ivf", {"nprobe": NPROBE}),
                                 ("pg", {"ef_search": EF})):
                res = db.dsq(q, path, k=K, recursive=rec, exclude=exc,
                             executor=name, **params)
                ids = [int(i) for i in res.ids[0] if int(i) >= 0]
                scores = [float(s) for s, i in zip(res.scores[0], res.ids[0])
                          if int(i) >= 0]
                assert res.scope_size == len(scope), (strat, name)
                # every id is in the oracle scope, with the right score
                assert set(ids) <= scope, (strat, name, set(ids) - scope)
                osc = self.oracle.scores(q, ids)
                for i, s in zip(ids, scores):
                    assert abs(osc[i] - s) < 1e-4 * max(1.0, abs(s)), \
                        (strat, name, i, s, osc[i])
                # strategies must agree exactly, per executor
                prev = per_exec.setdefault(name, [ids, scores])
                assert prev[0] == ids, (name, strat, prev[0], ids)
                np.testing.assert_allclose(prev[1], scores, rtol=1e-6,
                                           atol=1e-6, err_msg=f"{name}")
            # exact executors must return the oracle's exact top-k
            # (tie-tolerant: a swapped id is fine if its score ties)
            for name in ("flat", "sharded"):
                ids, scores = per_exec[name]
                want_ids = {i for i, _ in want}
                for miss in want_ids - set(ids):
                    tie = min(scores) if scores else -np.inf
                    assert abs(dict(want)[miss] - tie) < 1e-5, \
                        (name, miss, dict(want)[miss], tie)
                np.testing.assert_allclose(
                    sorted(scores, reverse=True),
                    [s for _, s in want[: len(scores)]],
                    rtol=1e-5, atol=1e-5)
            # ivf device path vs its per-query loop oracle
            ivf = self.dbs[strat].executors["ivf"]
            cand = np.asarray(sorted(scope), dtype=np.uint32)
            ls, li = ivf.search_loop(q[None, :], K, candidate_ids=cand,
                                     nprobe=NPROBE)
            loop_ids = {int(i) for i in li[0] if int(i) >= 0}
            assert loop_ids == set(per_exec["ivf"][0]), (
                strat, loop_ids, per_exec["ivf"][0])

    def check_dsq_int8(self) -> None:
        """int8 executor rows: with ``rescore_k`` covering the whole store
        the exact fp32 rescore is exhaustive over the int8 phase's
        survivors, so the exact executors (flat, sharded) must reproduce
        the oracle's top-k *set* (k-boundary score ties tolerated — the
        quantization tolerance of the contract), the ivf int8 path must
        match its own fp32 top-k set, and every executor's returned scores
        must be true fp32 scores of in-scope ids."""
        q = self.rng.normal(size=DIM).astype(np.float32)
        path, rec, exc = self.random_scope()
        scope = self.oracle.resolve(path, rec, exc)
        want = self.oracle.topk(q, scope, K)
        k_max = max(len(self.oracle.vectors), 1)
        for strat, db in self.dbs.items():
            for name, params in (("flat", {}), ("sharded", {}),
                                 ("ivf", {"nprobe": NPROBE}),
                                 ("pg", {"ef_search": EF})):
                res = db.dsq(q, path, k=K, recursive=rec, exclude=exc,
                             executor=name, precision="int8",
                             rescore_k=k_max, **params)
                ids = [int(i) for i in res.ids[0] if int(i) >= 0]
                scores = [float(s) for s, i in
                          zip(res.scores[0], res.ids[0]) if int(i) >= 0]
                assert res.scope_size == len(scope), (strat, name)
                assert set(ids) <= scope, (strat, name, set(ids) - scope)
                osc = self.oracle.scores(q, ids)
                for i, s in zip(ids, scores):
                    assert abs(osc[i] - s) < 1e-4 * max(1.0, abs(s)), \
                        (strat, name, i, s, osc[i])
                if name in ("flat", "sharded"):
                    want_ids = {i for i, _ in want}
                    for miss in want_ids - set(ids):
                        tie = min(scores) if scores else -np.inf
                        assert abs(dict(want)[miss] - tie) < 1e-4, \
                            (strat, name, miss, dict(want)[miss], tie)
                if name == "ivf":
                    rf = db.dsq(q, path, k=K, recursive=rec, exclude=exc,
                                executor="ivf", **params)
                    f_ids = {int(i) for i in rf.ids[0] if int(i) >= 0}
                    f_sc = {int(i): float(s) for s, i in
                            zip(rf.scores[0], rf.ids[0]) if int(i) >= 0}
                    for miss in f_ids - set(ids):
                        tie = min(scores) if scores else -np.inf
                        assert abs(f_sc[miss] - tie) < 1e-4, \
                            (strat, miss, f_sc[miss], tie)

    def check_dsq_batch_int8(self) -> None:
        """int8 batch == int8 loop per executor (top-k sets + fp32 scores;
        the fp32 leg of :meth:`check_dsq_batch` keeps its bit-identity)."""
        B = 6
        qs = self.rng.normal(size=(B, DIM)).astype(np.float32)
        specs = [self.random_scope() for _ in range(B)]
        paths = [s[0] for s in specs]
        rec = [s[1] for s in specs]
        exc = [s[2] for s in specs]
        k_max = max(len(self.oracle.vectors), 1)
        for strat, db in self.dbs.items():
            for name, params in (("flat", {}), ("sharded", {}),
                                 ("ivf", {"nprobe": NPROBE}),
                                 ("pg", {"ef_search": EF})):
                batch = db.dsq_batch(qs, paths, k=K, recursive=rec,
                                     exclude=exc, executor=name,
                                     precision="int8", rescore_k=k_max,
                                     **params)
                for i, res in enumerate(batch):
                    loop = db.dsq(qs[i], paths[i], k=K, recursive=rec[i],
                                  exclude=exc[i], executor=name,
                                  precision="int8", rescore_k=k_max,
                                  **params)
                    got = {int(x) for x in res.ids[0] if int(x) >= 0}
                    ref = {int(x) for x in loop.ids[0] if int(x) >= 0}
                    if name == "pg":
                        # quantized beam traversal order is entry-dependent;
                        # assert scope membership + fp32 scores only
                        scope = self.oracle.resolve(paths[i], rec[i], exc[i])
                        assert got <= scope, (strat, i, got - scope)
                        continue
                    assert got == ref, (strat, name, i, got, ref)
                    np.testing.assert_allclose(
                        np.sort(res.scores[0][np.isfinite(res.scores[0])]),
                        np.sort(loop.scores[0][np.isfinite(loop.scores[0])]),
                        rtol=1e-5, atol=1e-5,
                        err_msg=f"int8/{strat}/{name}/{i}")

    def check_dsq_pq(self) -> None:
        """PQ executor rows, same contract as :meth:`check_dsq_int8`: with
        exhaustive ``rescore_k`` the exact fp32 rescore ranks every PQ-phase
        survivor, so flat/sharded must reproduce the oracle's top-k set
        (k-boundary ties tolerated), ivf-pq must match its own fp32 top-k
        set, and all returned scores are true fp32 scores of in-scope ids.
        Running after the fuzz's DSM/ingest ops also exercises the frozen
        codebook's incremental encode consistency."""
        q = self.rng.normal(size=DIM).astype(np.float32)
        path, rec, exc = self.random_scope()
        scope = self.oracle.resolve(path, rec, exc)
        want = self.oracle.topk(q, scope, K)
        k_max = max(len(self.oracle.vectors), 1)
        for strat, db in self.dbs.items():
            for name, params in (("flat", {}), ("sharded", {}),
                                 ("ivf", {"nprobe": NPROBE}),
                                 ("pg", {"ef_search": EF})):
                res = db.dsq(q, path, k=K, recursive=rec, exclude=exc,
                             executor=name, precision="pq",
                             rescore_k=k_max, **params)
                ids = [int(i) for i in res.ids[0] if int(i) >= 0]
                scores = [float(s) for s, i in
                          zip(res.scores[0], res.ids[0]) if int(i) >= 0]
                assert res.scope_size == len(scope), (strat, name)
                assert set(ids) <= scope, (strat, name, set(ids) - scope)
                osc = self.oracle.scores(q, ids)
                for i, s in zip(ids, scores):
                    assert abs(osc[i] - s) < 1e-4 * max(1.0, abs(s)), \
                        (strat, name, i, s, osc[i])
                if name in ("flat", "sharded"):
                    want_ids = {i for i, _ in want}
                    for miss in want_ids - set(ids):
                        tie = min(scores) if scores else -np.inf
                        assert abs(dict(want)[miss] - tie) < 1e-4, \
                            (strat, name, miss, dict(want)[miss], tie)
                if name == "ivf":
                    rf = db.dsq(q, path, k=K, recursive=rec, exclude=exc,
                                executor="ivf", **params)
                    f_ids = {int(i) for i in rf.ids[0] if int(i) >= 0}
                    f_sc = {int(i): float(s) for s, i in
                            zip(rf.scores[0], rf.ids[0]) if int(i) >= 0}
                    for miss in f_ids - set(ids):
                        tie = min(scores) if scores else -np.inf
                        assert abs(f_sc[miss] - tie) < 1e-4, \
                            (strat, miss, f_sc[miss], tie)

    def check_dsq_batch_pq(self) -> None:
        """pq batch == pq loop per executor (PG excepted: the quantized beam
        traversal is entry-dependent, so scope membership only)."""
        B = 6
        qs = self.rng.normal(size=(B, DIM)).astype(np.float32)
        specs = [self.random_scope() for _ in range(B)]
        paths = [s[0] for s in specs]
        rec = [s[1] for s in specs]
        exc = [s[2] for s in specs]
        k_max = max(len(self.oracle.vectors), 1)
        for strat, db in self.dbs.items():
            for name, params in (("flat", {}), ("sharded", {}),
                                 ("ivf", {"nprobe": NPROBE}),
                                 ("pg", {"ef_search": EF})):
                batch = db.dsq_batch(qs, paths, k=K, recursive=rec,
                                     exclude=exc, executor=name,
                                     precision="pq", rescore_k=k_max,
                                     **params)
                for i, res in enumerate(batch):
                    loop = db.dsq(qs[i], paths[i], k=K, recursive=rec[i],
                                  exclude=exc[i], executor=name,
                                  precision="pq", rescore_k=k_max,
                                  **params)
                    got = {int(x) for x in res.ids[0] if int(x) >= 0}
                    ref = {int(x) for x in loop.ids[0] if int(x) >= 0}
                    if name == "pg":
                        scope = self.oracle.resolve(paths[i], rec[i], exc[i])
                        assert got <= scope, (strat, i, got - scope)
                        continue
                    assert got == ref, (strat, name, i, got, ref)
                    np.testing.assert_allclose(
                        np.sort(res.scores[0][np.isfinite(res.scores[0])]),
                        np.sort(loop.scores[0][np.isfinite(loop.scores[0])]),
                        rtol=1e-5, atol=1e-5,
                        err_msg=f"pq/{strat}/{name}/{i}")

    def check_dsq_batch(self) -> None:
        B = 6
        qs = self.rng.normal(size=(B, DIM)).astype(np.float32)
        specs = [self.random_scope() for _ in range(B)]
        paths = [s[0] for s in specs]
        rec = [s[1] for s in specs]
        exc = [s[2] for s in specs]
        for strat, db in self.dbs.items():
            for name, params in (("flat", {}), ("sharded", {}),
                                 ("ivf", {"nprobe": NPROBE}),
                                 ("pg", {"ef_search": EF})):
                batch = db.dsq_batch(qs, paths, k=K, recursive=rec,
                                     exclude=exc, executor=name, **params)
                for i, res in enumerate(batch):
                    loop = db.dsq(qs[i], paths[i], k=K, recursive=rec[i],
                                  exclude=exc[i], executor=name, **params)
                    got = {int(x) for x in res.ids[0] if int(x) >= 0}
                    ref = {int(x) for x in loop.ids[0] if int(x) >= 0}
                    assert got == ref, (strat, name, i, got, ref)
                    np.testing.assert_allclose(
                        np.sort(res.scores[0][np.isfinite(res.scores[0])]),
                        np.sort(loop.scores[0][np.isfinite(loop.scores[0])]),
                        rtol=1e-5, atol=1e-5,
                        err_msg=f"{strat}/{name}/{i}")
                if name in ("flat", "sharded"):
                    # batch must be *bit*-identical to the loop here
                    for i, res in enumerate(batch):
                        loop = db.dsq(qs[i], paths[i], k=K,
                                      recursive=rec[i], exclude=exc[i],
                                      executor=name)
                        np.testing.assert_array_equal(res.ids, loop.ids)
                        np.testing.assert_array_equal(res.scores,
                                                      loop.scores)


WEIGHTS = [("ingest", 0.22), ("mkdir", 0.12), ("move", 0.14),
           ("merge", 0.10), ("rmdir", 0.07), ("delete", 0.10),
           ("crash_recover", 0.05), ("maintenance", 0.06),
           ("noop", 0.14)]


def _seed_corpus(state: FuzzState) -> None:
    """A real tree (depth >= 3) plus enough entries to build ANN on."""
    for _ in range(8):
        state.op_mkdir()
    state.op_ingest(48)
    for _ in range(4):
        state.op_mkdir()
    state.op_ingest(24)


def _run_fuzz(state: FuzzState, n_ops: int, check_every: int = 6) -> None:
    _seed_corpus(state)
    for db in state.dbs.values():
        db.build_ann("flat")
        db.build_ann("sharded")
        db.build_ann("ivf", n_lists=8)
        db.build_ann("pg", max_degree=8, ef_construction=24)
    kinds = [k for k, _ in WEIGHTS]
    probs = np.asarray([w for _, w in WEIGHTS])
    probs /= probs.sum()
    for step in range(n_ops):
        kind = kinds[int(state.rng.choice(len(kinds), p=probs))]
        getattr(state, f"op_{kind}", lambda: None)()
        for db in state.dbs.values():
            db.check_invariants()
        if (step + 1) % check_every == 0:
            state.check_dsq()
    state.check_dsq()
    state.check_dsq_batch()
    state.check_dsq_int8()
    state.check_dsq_batch_int8()
    state.check_dsq_pq()
    state.check_dsq_batch_pq()
    state.op_crash_recover()


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_fuzz(seed):
    with tempfile.TemporaryDirectory() as tmp:
        state = FuzzState(seed, tmp)
        _run_fuzz(state, n_ops=30)


def test_differential_crash_replay():
    """crash+recover differential: journal a DSM BEGIN without applying it
    (the crash window between append and mutation), reopen-free recover()
    must roll it forward on every strategy to exactly the oracle's state."""
    with tempfile.TemporaryDirectory() as tmp:
        state = FuzzState(seed=42, tmpdir=tmp)
        _seed_corpus(state)
        for db in state.dbs.values():
            db.build_ann("flat")
            db.build_ann("sharded")
            db.build_ann("ivf", n_lists=8)
            db.build_ann("pg", max_degree=8, ef_construction=24)
        # pick a valid move from current oracle state
        for _ in range(50):
            src = state._pick_dir(non_root=True)
            npar = state._pick_dir()
            if (src and npar is not None
                    and not P.is_ancestor(src, npar)
                    and npar[: len(src)] != src
                    and npar + (src[-1],) not in state.oracle.dirs
                    and npar != src[:-1]):
                break
        else:
            pytest.skip("no valid move found")
        op = DSM("move", P.to_str(src), P.to_str(npar))
        for strat, db in state.dbs.items():
            db._dsm["fs"].journal.begin(op)       # BEGIN, no COMMIT: "crash"
            replayed = db.recover()
            assert [o.src for o in replayed["fs"]] == [op.src], strat
            db.check_invariants()
        state.oracle.move(op.src, op.dst)
        state.check_dsq()
        state.check_dsq_batch()


def test_oracle_self_consistency():
    """The oracle's own prefix semantics (sanity for the net itself)."""
    o = PyOracle()
    o.ingest([0, 1, 2], np.eye(3, DIM, dtype=np.float32),
             ["/a/", "/a/b/", "/c/"])
    assert o.resolve("/a/") == {0, 1}
    assert o.resolve("/a/", recursive=False) == {0}
    assert o.resolve("/", exclude=["/a/b/"]) == {0, 2}
    o.move("/a/b/", "/c/")
    assert o.resolve("/c/") == {1, 2}
    o.merge("/c/", "/a/")
    assert o.resolve("/a/") == {0, 1, 2}
    assert o.remove("/a/") == {0, 1, 2}
    assert o.entries == {}


@pytest.mark.parametrize("seed", [3])
def test_differential_fuzz_perturbed_artifact(seed):
    """Differential fuzz under a randomly-perturbed calibration artifact:
    measured decisions (crossover threshold, rescore factor, precision
    flips, kernel block shapes, nprobe default) may change *plans*, but the
    recall/consistency gates above must hold for ANY artifact — the clamp
    envelope in CostModel is what makes perturbation safe. All three
    strategy DBs share one model, so cross-strategy bit-identity holds."""
    import jax

    from repro.kernels import ops as kops
    from repro.vectordb.costmodel import (TUNABLE_KERNELS,
                                          install_kernel_tuning,
                                          resolve_calibration)
    rng = np.random.default_rng(seed)

    def term():
        return {"a": float(rng.uniform(0, 2e5)),
                "per_byte": float(rng.uniform(0, 5))}

    art = {
        "schema_version": 1, "backend": jax.default_backend(), "dim": DIM,
        "terms": {
            "gather_threshold": float(rng.uniform(0.0, 0.6)),
            "rescore_factor": int(rng.integers(1, 9)),
            "nprobe": {"default": int(rng.integers(1, 64))},
            "scan_ns": {p: term() for p in ("fp32", "int8", "pq")},
            "gather_ns": {"a": float(rng.uniform(0, 2e5)),
                          "per_row": float(rng.uniform(0, 2e3))},
            "rescore_ns": {"a": float(rng.uniform(0, 2e5)),
                           "per_row": float(rng.uniform(0, 2e3))},
            "kernel_blocks": {
                name: {"block_q": int(rng.choice([2, 4, 8, 16])),
                       "block_n": int(rng.choice([64, 128, 256, 512,
                                                  1024])),
                       "us": 1.0}
                for name in TUNABLE_KERNELS},
            "scheduler": {"max_batch": int(rng.integers(1, 64)),
                          "max_wait_ms": float(rng.uniform(0.5, 8.0)),
                          "service_us": {}},
        },
    }
    model = resolve_calibration(art)
    assert model.source == "measured"
    try:
        with tempfile.TemporaryDirectory() as tmp:
            state = FuzzState(seed, tmp)
            for db in state.dbs.values():
                db.store.cost_model = model
            install_kernel_tuning(model)
            _run_fuzz(state, n_ops=18)
    finally:
        kops.set_block_overrides({})
