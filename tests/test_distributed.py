"""Multi-device semantics, tested in a subprocess with 8 simulated host
devices (the main pytest process must keep seeing exactly 1 device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_ep_shardmap_matches_local():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        from repro.models.common import ArchConfig
        from repro.models import moe as M
        from repro.models.layers import init_params
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=32,
                         n_heads=4, d_ff=64, vocab_size=64, n_experts=8,
                         moe_top_k=2, n_shared_experts=1, moe_d_ff=16,
                         capacity_factor=64.0, dtype="float32")
        params = init_params(M.moe_schema(cfg), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, 32)),
                        jnp.float32)
        y_local = M.moe_apply(params, x, cfg, mesh=None)
        with mesh:
            y_ep = jax.jit(lambda p, x: M.moe_apply(p, x, cfg, mesh))(params, x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_local),
                                   rtol=2e-4, atol=2e-4)
        print("EP==local OK")
    """)


def test_int8_psum_cross_pod():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.training.train_step import int8_psum
        mesh = compat.make_mesh((2, 4), ("pod", "data"))
        g = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)),
                        jnp.float32)

        def f(g):
            return int8_psum({"g": g}, "pod")["g"]

        out = compat.shard_map(f, mesh=mesh, in_specs=P("pod", None),
                               out_specs=P("pod", None), check_vma=False)(g)
        # mean across the pod axis, with int8 quantization error bounds
        want = jnp.broadcast_to(g.mean(axis=0, keepdims=True), g.shape)
        err = np.abs(np.asarray(out) - np.asarray(want)).max()
        scale = float(np.abs(np.asarray(g)).max()) / 127.0
        assert err <= scale + 1e-6, (err, scale)
        print("int8 psum OK", err)
    """)


def test_distributed_scoped_search_exact():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_for_devices
        from repro.distributed.search import make_scoped_search
        mesh = make_mesh_for_devices(model_parallelism=2)
        n, d, k, q = 1024, 32, 10, 4
        rng = np.random.default_rng(0)
        db = rng.normal(size=(n, d)).astype(np.float32)
        mask = (rng.random(n) < 0.3).astype(np.int8)
        queries = rng.normal(size=(q, d)).astype(np.float32)
        fn = make_scoped_search(mesh, n, d, k)
        scores, ids = fn(jnp.asarray(db), jnp.asarray(mask),
                         jnp.asarray(queries))
        ref = queries @ db.T
        ref[:, mask == 0] = -np.inf
        want = np.argsort(-ref, axis=1)[:, :k]
        got_scores = np.asarray(scores)
        want_scores = -np.sort(-ref, axis=1)[:, :k]
        np.testing.assert_allclose(got_scores, want_scores, rtol=1e-4,
                                   atol=1e-4)
        # ids must be valid candidates achieving those scores
        for qi in range(q):
            for s, i in zip(got_scores[qi], np.asarray(ids)[qi]):
                assert mask[i]
                np.testing.assert_allclose(ref[qi, i], s, rtol=1e-4)
        print("scoped search OK")
    """)


def test_distributed_multi_scope_search_exact():
    """Packed batch masks through shard_map: one launch ranks a mixed-scope
    request batch; every shard reads only the uint32 words covering its
    rows (32x less mask traffic than dense int8)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_for_devices
        from repro.distributed.search import make_multi_scope_search
        from repro.core.idset import RoaringBitmap
        mesh = make_mesh_for_devices(model_parallelism=2)
        n, d, k, q, S = 1024, 32, 10, 6, 3
        rng = np.random.default_rng(0)
        db = rng.normal(size=(n, d)).astype(np.float32)
        scopes = [RoaringBitmap(np.nonzero(rng.random(n) < 0.3)[0]
                                .astype(np.uint32)) for _ in range(S)]
        words = RoaringBitmap.pack_words(scopes, n)
        sids = rng.integers(0, S, size=q).astype(np.int32)
        queries = rng.normal(size=(q, d)).astype(np.float32)
        fn = make_multi_scope_search(mesh, n, d, k)
        scores, ids = fn(jnp.asarray(db), jnp.asarray(words),
                         jnp.asarray(sids), jnp.asarray(queries))
        masks = np.stack([s.to_bool_mask(n) for s in scopes])
        ref = queries @ db.T
        ref[~masks[sids]] = -np.inf
        want = -np.sort(-ref, axis=1)[:, :k]
        np.testing.assert_allclose(np.asarray(scores), want,
                                   rtol=1e-4, atol=1e-4)
        for qi in range(q):
            for s, i in zip(np.asarray(scores)[qi], np.asarray(ids)[qi]):
                assert masks[sids[qi], i]
                np.testing.assert_allclose(ref[qi, i], s, rtol=1e-4)
        print("multi-scope distributed search OK")
    """)


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto an 8-device mesh (grow)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import CheckpointManager
        devs = jax.devices()
        m4 = jax.sharding.Mesh(np.array(devs[:4]).reshape(4, 1),
                               ("data", "model"))
        m8 = jax.sharding.Mesh(np.array(devs).reshape(8, 1),
                               ("data", "model"))
        x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                           NamedSharding(m4, P("data", None)))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(3, {"x": x})
            restored, step, _ = mgr.restore(
                {"x": jnp.zeros((8, 8), jnp.float32)},
                shardings={"x": NamedSharding(m8, P("data", None))})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.arange(64).reshape(8, 8))
        shards = restored["x"].sharding.num_devices if hasattr(
            restored["x"].sharding, "num_devices") else 8
        print("elastic reshard OK", shards)
    """)


def test_train_step_cross_pod_int8_runs():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        from repro.configs import smoke_config
        from repro.models import model_schema
        from repro.models.layers import init_params
        from repro.training.optimizer import OptConfig, init_opt_state
        from repro.training.train_step import make_train_step
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = smoke_config("qwen3-0.6b").replace(n_layers=1, d_model=32,
                                                 d_ff=64, vocab_size=64,
                                                 head_dim=8, n_kv_heads=2)
        params = init_params(model_schema(cfg), jax.random.PRNGKey(0),
                             cfg.param_dtype())
        opt = init_opt_state(params)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, 64, size=(8, 16)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        step = jax.jit(make_train_step(cfg, OptConfig(), mesh,
                                       cross_pod_int8=True))
        with mesh:
            p2, o2, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("cross-pod int8 train OK", float(m["loss"]))
    """)
