"""Crash-consistent DSM at scale: journal replay, batched region-scheduled
maintenance, write-amplification accounting, delta-patched mask cache.

The contract under test mirrors §IV-A: BEGIN is durable before a mutation
runs, a lost COMMIT is detected and rolled forward idempotently on restart,
overlapping mutations apply in submission order (FIFO region scheduling),
and the write-amplification counters reproduce the Table II contrast —
TrieHI's topological O(depth) maintenance vs the PE-* expansion costs.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (DSM, DSMExecutor, DSMJournal, DSMStats,
                        RegionLockManager, STRATEGIES, make_scope_index)
from repro.core import paths as P
from repro.vectordb import DirectoryVectorDB, ScopeMaskCache


# --------------------------------------------------------------- journal
def test_journal_reopen_continues_seq(tmp_path):
    """Regression: a reopened journal restarted seq at 0, so recover()
    paired the OLD commit with the NEW begin and silently masked the crash
    suspect (begin+commit, reopen, begin, crash -> zero suspects)."""
    jp = str(tmp_path / "dsm.journal")
    j1 = DSMJournal(jp)
    seq0 = j1.begin(DSM("move", "/a/", "/b/"))
    j1.commit(seq0)

    j2 = DSMJournal(jp)                    # process restart
    seq1 = j2.begin(DSM("move", "/x/", "/y/"))
    # crash here: no commit for seq1
    assert seq1 > seq0, "reopen must continue the persisted sequence"
    suspects = DSMJournal.recover(jp)
    assert len(suspects) == 1
    assert suspects[0] == DSM("move", "/x/", "/y/")


def test_journal_tolerates_torn_tail(tmp_path):
    jp = str(tmp_path / "dsm.journal")
    j = DSMJournal(jp)
    seq = j.begin(DSM("mkdir", "/a/"))
    j.commit(seq)
    j.begin(DSM("move", "/a/", "/b/"))
    with open(jp, "a") as f:
        f.write('{"event": "comm')        # crash mid-append
    reopened = DSMJournal(jp)
    assert [op for _, op in reopened.uncommitted()] == [
        DSM("move", "/a/", "/b/")]
    # and new seqs continue past everything parseable
    new_seq = reopened.begin(DSM("mkdir", "/c/"))
    assert new_seq > seq
    # regression: the torn tail must be TRUNCATED on reopen — otherwise the
    # post-reopen BEGIN glues onto the torn line and a second restart loses
    # it (and every later record) as a crash suspect
    rescanned = DSMJournal(jp)
    assert [op for _, op in rescanned.uncommitted()] == [
        DSM("move", "/a/", "/b/"), DSM("mkdir", "/c/")]


def test_journal_compact_keeps_only_suspects(tmp_path):
    jp = str(tmp_path / "dsm.journal")
    j = DSMJournal(jp)
    for i in range(50):
        j.commit(j.begin(DSM("mkdir", f"/d{i}/")))
    crash_seq = j.begin(DSM("move", "/d0/", "/d1/"))
    size_before = os.path.getsize(jp)
    j.compact()
    assert os.path.getsize(jp) < size_before
    reopened = DSMJournal(jp)
    assert reopened.uncommitted() == [(crash_seq, DSM("move", "/d0/", "/d1/"))]
    assert reopened.begin(DSM("mkdir", "/x/")) > crash_seq


def test_journal_group_commit_roundtrip(tmp_path):
    jp = str(tmp_path / "dsm.journal")
    j = DSMJournal(jp)
    ops = [DSM("mkdir", f"/d{i}/") for i in range(4)]
    seqs = j.begin_many(ops)
    assert seqs == sorted(seqs)
    j.commit_many(seqs[:2])
    j.abort(seqs[2])
    # seqs[3] stays uncommitted; a reopen must surface exactly it
    assert [s for s, _ in DSMJournal(jp).uncommitted()] == [seqs[3]]


# ----------------------------------------------------- region scheduling
def test_region_lock_fifo_fairness():
    """A later waiter must not barge past an earlier one on the same region
    (the starvation/reorder hole), while disjoint regions stay concurrent."""
    mgr = RegionLockManager()
    holder = mgr.acquire([P.parse("/x/")])
    tok_b = mgr.enqueue([P.parse("/x/")])
    tok_c = mgr.enqueue([P.parse("/x/sub/")])   # overlaps b's region
    order = []

    def run(tok, label):
        mgr.wait(tok)
        order.append(label)
        mgr.release(tok)

    # start c's thread FIRST: under the old barging lock it could acquire
    # before b after the holder releases
    tc = threading.Thread(target=run, args=(tok_c, "c"))
    tc.start()
    time.sleep(0.02)
    tb = threading.Thread(target=run, args=(tok_b, "b"))
    tb.start()
    time.sleep(0.02)
    # a disjoint region acquires immediately even with /x/ waiters queued
    t0 = time.time()
    disjoint = mgr.acquire([P.parse("/y/")])
    assert time.time() - t0 < 0.5
    mgr.release(disjoint)
    mgr.release(holder)
    tb.join(timeout=5)
    tc.join(timeout=5)
    assert order == ["b", "c"], order


@pytest.mark.parametrize("strategy", list(STRATEGIES))
@pytest.mark.parametrize("max_workers", [1, 4])
def test_apply_many_matches_sequential(strategy, max_workers, tmp_path):
    """Group-committed batch == sequential application: overlapping ops in
    submission order, invalid ops surfaced per-op, journal fully resolved."""
    rng = np.random.default_rng(hash((strategy, max_workers)) % 2 ** 32)

    def seed(idx):
        for eid in range(40):
            idx.insert(eid, f"/t{eid % 5}/d{eid % 3}/")

    idx = make_scope_index(strategy)
    twin = make_scope_index(strategy)
    seed(idx)
    seed(twin)
    tops = [f"/t{i}/" for i in range(5)]
    ops = []
    for i in range(12):
        a, b = rng.choice(5, size=2, replace=False)
        kind = ["move", "merge", "remove", "mkdir"][int(rng.integers(0, 4))]
        if kind == "move":
            ops.append(DSM("move", f"/t{a}/d{i % 3}/", f"/t{b}/"))
        elif kind == "merge":
            ops.append(DSM("merge", f"/t{a}/d{i % 3}/", f"/t{b}/d{(i + 1) % 3}/"))
        elif kind == "remove":
            ops.append(DSM("remove", f"/t{a}/d{i % 3}/"))
        else:
            ops.append(DSM("mkdir", f"/t{a}/fresh{i}/"))

    jp = str(tmp_path / f"{strategy}.journal")
    ex = DSMExecutor(idx, DSMJournal(jp))
    stats = DSMStats()
    result = ex.apply_many(ops, stats=stats, max_workers=max_workers)
    for op in ops:
        try:
            DSMExecutor(twin).apply(op)
        except (KeyError, ValueError):
            pass
    idx.check_invariants()
    for probe in tops + ["/", "/t0/d0/", "/t3/d1/"]:
        for rec in (True, False):
            assert (set(idx.resolve(probe, recursive=rec).to_array().tolist())
                    == set(twin.resolve(probe, recursive=rec)
                           .to_array().tolist())), (probe, rec)
    assert result.applied == sum(1 for e in result.errors if e is None)
    assert stats.ops == result.applied
    # every BEGIN in the journal paired with a COMMIT or ABORT
    assert DSMJournal(jp).uncommitted() == []


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_concurrent_resolve_during_dsm_batch(strategy):
    """Serving reads racing batched maintenance: resolve copies/unions the
    same aggregate containers the DSM workers mutate in place — the
    aggregate latch must keep every container read intact (no torn bitmaps,
    no dict-changed-size errors). Full *snapshot* atomicity across a
    multi-key resolution is TrieHI's alone: its recursive read is one
    aggregate copy, while PE-ONLINE's key-enumeration union can observe a
    move mid-flight (the §IV-A consistency contrast) — so the membership
    invariant is asserted only for TrieHI."""
    idx = make_scope_index(strategy)
    for eid in range(200):
        idx.insert(eid, f"/t{eid % 8}/d{(eid // 8) % 2}/")
    ex = DSMExecutor(idx)
    stop = threading.Event()
    errors: list = []

    def reader():
        try:
            while not stop.is_set():
                got = idx.resolve("/", recursive=True)
                if strategy == "triehi":
                    assert len(got) == 200      # single-aggregate snapshot
                else:
                    assert len(got) <= 200
                for t in range(8):
                    idx.resolve(f"/t{t}/", recursive=True)
                    idx.resolve(f"/t{t}/", recursive=False)
        except Exception as e:                  # pragma: no cover - failure
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        for r in range(2):
            ops = [DSM("move", f"/t{t}/d{r}/", f"/x{r}_{t}/")
                   for t in range(8)]
            res = ex.apply_many(ops, max_workers=4)
            assert all(e is None for e in res.errors), res.errors
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
    assert not errors, errors
    idx.check_invariants()


# --------------------------------------------------------- crash recovery
def _seed_crash_index(strategy):
    idx = make_scope_index(strategy)
    for eid in range(30):
        idx.insert(eid, f"/t{eid % 3}/d{eid % 2}/x{eid % 2}/"
                   if eid % 5 == 0 else f"/t{eid % 3}/d{eid % 2}/")
    return idx


def _crash_workload():
    return [
        DSM("move", "/t0/d0/", "/t1/"),
        DSM("merge", "/t1/d0/", "/t2/d0/"),
        DSM("remove", "/t2/d0/x0/"),
        DSM("move", "/t0/", "/t2/d1/"),
        DSM("move", "/missing/", "/t1/"),        # invalid: must abort
        DSM("merge", "/t2/d1/t0/d1/", "/t1/d1/"),
        DSM("mkdir", "/t1/new/"),
        DSM("remove", "/t1/d1/"),
    ]


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_crash_recovery_at_every_kill_point(strategy, tmp_path):
    """Property: kill between BEGIN and COMMIT at every op index, in both
    kill modes (mutation never ran / mutation ran, COMMIT lost). Replay must
    be idempotent and leave resolves bit-identical to an uncrashed twin, and
    ``check_invariants`` (run inside ``recover``) must pass."""
    ops = _crash_workload()
    probes = ["/", "/t0/", "/t1/", "/t2/", "/t1/d0/", "/t2/d0/", "/t2/d1/",
              "/t1/new/", "/t2/d1/t0/"]
    for kill in range(len(ops)):
        for mode in ("before_apply", "after_apply"):
            jp = str(tmp_path / f"{strategy}-{kill}-{mode}.journal")
            idx = _seed_crash_index(strategy)
            ex = DSMExecutor(idx, DSMJournal(jp))
            for op in ops[:kill]:
                try:
                    ex.apply(op)
                except (KeyError, ValueError):
                    pass
            # the crashing op: BEGIN reaches the journal, COMMIT never does
            ex.journal.begin(ops[kill])
            crashed_applied = False
            if mode == "after_apply":
                try:
                    ops[kill].apply(idx)
                    crashed_applied = True
                except (KeyError, ValueError):
                    pass

            # restart: fresh executor over the restored index state
            ex2 = DSMExecutor(idx, DSMJournal(jp))
            outcome = ex2.recover()          # runs check_invariants
            replayed = [op for op, did, _ in outcome if did]
            if crashed_applied:
                assert replayed == [], (strategy, kill, mode)

            twin = _seed_crash_index(strategy)
            for op in ops[:kill + 1]:
                try:
                    op.apply(twin)
                except (KeyError, ValueError):
                    pass
            for probe in probes:
                for rec in (True, False):
                    got = set(idx.resolve(probe, recursive=rec)
                              .to_array().tolist())
                    want = set(twin.resolve(probe, recursive=rec)
                               .to_array().tolist())
                    assert got == want, (strategy, kill, mode, probe, rec)
            # replay resolved every suspect: a second restart is a no-op
            assert ex2.recover() == []


def test_db_recover_replays_across_restart(tmp_path):
    """DirectoryVectorDB wiring: the reopened journal (continued seqs) plus
    explicit recover() rolls the lost mutation forward."""
    jp = str(tmp_path / "db.journal")
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(20, 8)).astype(np.float32)
    paths = [f"/a/p{i % 2}/" if i % 2 else f"/b/q{i % 3}/" for i in range(20)]

    db = DirectoryVectorDB(dim=8, journal_path=jp)
    db.ingest(vecs, paths)
    db.move("/a/p1/", "/b/")                         # committed history
    # crash between BEGIN and the mutation:
    db._dsm["fs"].journal.begin(DSM("move", "/b/p1/", "/a/"))

    db2 = DirectoryVectorDB(dim=8, journal_path=jp)  # restart
    db2.ingest(vecs, paths)                          # restore index state
    db2.move("/a/p1/", "/b/")                        # re-applied history
    replayed = db2.recover()
    assert replayed["fs"] == [DSM("move", "/b/p1/", "/a/")]
    assert db2.namespaces["fs"].has_dir("/a/p1/")
    assert not db2.namespaces["fs"].has_dir("/b/p1/")
    db2.check_invariants()


# ------------------------------------------------------------------ remove
@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_remove_drops_subtree_everywhere(strategy):
    idx = make_scope_index(strategy)
    layout = {0: "/keep/", 1: "/gone/", 2: "/gone/sub/", 3: "/gone/sub/deep/",
              4: "/keep/gone/"}
    for eid, p in layout.items():
        idx.insert(eid, p)
    stats = DSMStats()
    removed = idx.remove("/gone/", stats=stats)
    assert set(removed.to_array().tolist()) == {1, 2, 3}
    assert not idx.has_dir("/gone/")
    assert idx.has_dir("/keep/gone/")                # sibling name untouched
    assert set(idx.resolve("/", True)) == {0, 4}
    assert idx.entry_dir(2) is None                  # catalog unbound
    assert stats.entries_unbound == 3
    assert stats.dirs_removed == 3
    idx.check_invariants()
    with pytest.raises(KeyError):
        idx.remove("/gone/")
    with pytest.raises(ValueError):
        idx.remove("/")


def test_rmdir_tombstones_and_purges_other_namespaces():
    rng = np.random.default_rng(1)
    vecs = rng.normal(size=(12, 8)).astype(np.float32)
    fs = [f"/docs/d{i % 3}/" for i in range(12)]
    time_ns = [f"/2026/m{i % 2}/" for i in range(12)]
    db = DirectoryVectorDB(dim=8, scope_strategy="triehi")
    db.ingest(vecs, fs, namespaces={"time": time_ns})
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=2)
    db.build_ann("pg", max_degree=4, ef_construction=8)

    removed = db.rmdir("/docs/d1/")
    want_gone = {i for i in range(12) if i % 3 == 1}
    assert set(removed.tolist()) == want_gone
    assert db.store.n_deleted == len(want_gone)
    # purged from the OTHER namespace too
    assert set(db.namespaces["time"].resolve("/", True)
               .to_array().tolist()) == set(range(12)) - want_gone
    db.check_invariants()
    # no executor may surface a tombstoned id, even unscoped
    q = vecs[list(want_gone)[0]]
    for executor in ("flat", "ivf", "pg"):
        r = db.dsq(q, "/", k=12, executor=executor)
        assert not (set(r.ids[0][r.ids[0] >= 0].tolist()) & want_gone), executor


def test_remove_region_locked_and_journaled(tmp_path):
    jp = str(tmp_path / "rm.journal")
    idx = make_scope_index("triehi")
    for eid in range(6):
        idx.insert(eid, f"/a/b{eid % 2}/")
    ex = DSMExecutor(idx, DSMJournal(jp))
    removed = ex.apply(DSM("remove", "/a/b0/"))
    assert set(removed.to_array().tolist()) == {0, 2, 4}
    assert DSMJournal(jp).uncommitted() == []        # committed
    assert DSM("remove", "/a/b0/").affected_region() == [("a", "b0")]


# ------------------------------------------------- delta-patched mask cache
def _patch_db(n_top=6, per_dir=24, dim=16, seed=3):
    rng = np.random.default_rng(seed)
    paths = []
    for t in range(n_top):
        for j in range(per_dir):
            paths.append(f"/s{t}/" if j % 2 else f"/s{t}/in{t}/")
    vecs = rng.normal(size=(len(paths), dim)).astype(np.float32)
    db = DirectoryVectorDB(dim=dim, scope_strategy="triehi")
    db.ingest(vecs, paths)
    db.build_ann("flat")
    queries = rng.normal(size=(10, dim)).astype(np.float32)
    return db, queries


def test_mask_cache_patches_instead_of_evicting():
    """A MOVE must leave every simple cached scope on the affected ancestor
    chains *patched and valid* — and the patched masks must stay bit-identical
    to per-request resolution."""
    db, q = _patch_db()
    scopes = ["/", "/s0/", "/s1/", "/s2/", "/s3/", "/s4/", "/s5/", "/", "/s0/",
              "/s1/"]
    db.dsq_batch(q, scopes, k=5)
    cache = db.planner().cache
    n_before = cache.stats()["entries"]
    assert n_before > 0

    db.move("/s0/in0/", "/s1/")          # /s0/ loses S, /s1/ gains S
    assert cache.patched >= 2            # both chain anchors patched
    valid, total = cache.revalidate(db.namespaces["fs"], len(db.store))
    assert total == n_before
    assert valid == total, "every entry must survive the move (patched)"

    after = db.dsq_batch(q, scopes, k=5)
    acct = after[0].batch
    assert acct.scope_cache_hits == len(set(scopes)), \
        "post-DSM batch must be served fully from the patched cache"
    for i, scope in enumerate(scopes):
        r = db.dsq(q[i], scope, k=5)
        np.testing.assert_array_equal(after[i].ids, r.ids, err_msg=scope)
        np.testing.assert_array_equal(after[i].scores, r.scores)
        assert after[i].scope_size == r.scope_size


def test_mask_cache_patch_remove_and_merge():
    db, q = _patch_db()
    db.dsq_batch(q[:4], ["/", "/s2/", "/s3/", "/s4/"], k=5)
    cache = db.planner().cache
    db.merge("/s2/in2/", "/s3/in3/")     # "/" is the common ancestor: only
    db.rmdir("/s4/in4/")                 # chains below it get patched
    valid, total = cache.revalidate(db.namespaces["fs"], len(db.store))
    assert valid == total
    for i, scope in enumerate(["/", "/s2/", "/s3/", "/s4/"]):
        r = db.dsq(q[i], scope, k=5)
        b = db.dsq_batch(q[i:i + 1], [scope], k=5)[0]
        np.testing.assert_array_equal(b.ids, r.ids, err_msg=scope)
        assert b.scope_size == r.scope_size


def test_mask_cache_evicts_composite_entries():
    """Exclusion composites and non-recursive scopes on the affected chain
    cannot take the plain delta: they must evict (and re-resolve correctly),
    never serve a stale mask."""
    db, q = _patch_db()
    db.dsq_batch(q[:3], ["/", "/", "/s1/"], k=5,
                 exclude=[["/s0/"], [], []], recursive=[True, True, False])
    cache = db.planner().cache
    db.move("/s1/in1/", "/s0/")
    assert cache.delta_evictions >= 1    # the "/ minus /s0/" composite
    for spec in [("/", ["/s0/"], True), ("/", [], True), ("/s1/", [], False)]:
        path, exc, rec = spec
        r = db.dsq(q[0], path, k=5, exclude=exc, recursive=rec)
        b = db.dsq_batch(q[:1], [path], k=5, exclude=[exc], recursive=[rec])[0]
        np.testing.assert_array_equal(b.ids, r.ids, err_msg=str(spec))
        assert b.scope_size == r.scope_size


def test_mask_cache_patch_through_pallas_kernel():
    """The batched ``bitmap_patch`` kernel path produces the same patched
    words as the numpy oracle path."""
    db, q = _patch_db()
    db.planner().cache.use_pallas = True
    scopes = ["/", "/s0/", "/s1/"]
    db.dsq_batch(q[:3], scopes, k=5)     # populate + materialize words
    cache = db.planner().cache
    db.move("/s0/in0/", "/s1/")
    assert cache.patched >= 2
    after = db.dsq_batch(q[:3], scopes, k=5)
    for i, scope in enumerate(scopes):
        r = db.dsq(q[i], scope, k=5)
        np.testing.assert_array_equal(after[i].ids, r.ids, err_msg=scope)


def test_mask_cache_never_resurrects_entry_staled_by_delete():
    """A point delete bumps chain epochs without a delta event; a later
    MOVE touching the same chain must EVICT the stale entry, not re-stamp
    it valid with only the move's delta applied (the deleted id would
    reappear in served masks)."""
    db, q = _patch_db()
    db.dsq_batch(q[:2], ["/s0/", "/s1/"], k=5)
    cache = db.planner().cache
    victim = int(db.namespaces["fs"].resolve("/s0/").to_array()[0])
    db.delete(victim)                    # un-evented epoch bump on /s0/ chain
    db.move("/s0/in0/", "/s1/")          # evented: touches the same chain
    assert cache.delta_evictions >= 1    # stale /s0/ entry evicted, not patched
    r = db.dsq(q[0], "/s0/", k=5)
    b = db.dsq_batch(q[:1], ["/s0/"], k=5)[0]
    np.testing.assert_array_equal(b.ids, r.ids)
    assert victim not in b.ids[0].tolist()
    assert b.scope_size == r.scope_size


def test_recover_finishes_rmdir_contract(tmp_path):
    """A REMOVE whose COMMIT was lost must, after replay, still purge the
    other namespaces and tombstone the store rows."""
    jp = str(tmp_path / "db.journal")
    rng = np.random.default_rng(11)
    vecs = rng.normal(size=(10, 8)).astype(np.float32)
    fs = [f"/docs/d{i % 2}/" for i in range(10)]
    tns = [f"/2026/m{i % 2}/" for i in range(10)]

    db = DirectoryVectorDB(dim=8, journal_path=jp)
    db.ingest(vecs, fs, namespaces={"time": tns})
    db._dsm["fs"].journal.begin(DSM("remove", "/docs/d1/"))   # crash pre-apply

    db2 = DirectoryVectorDB(dim=8, journal_path=jp)
    db2.ingest(vecs, fs, namespaces={"time": tns})
    replayed = db2.recover()
    assert replayed["fs"] == [DSM("remove", "/docs/d1/")]
    gone = {i for i in range(10) if i % 2 == 1}
    assert db2.store.n_deleted == len(gone)
    assert not (set(db2.namespaces["time"].resolve("/").to_array().tolist())
                & gone)
    db2.check_invariants()


def test_apply_many_rejects_malformed_op_cleanly():
    """A malformed op (unparseable region) must fail the batch BEFORE any
    BEGIN or FIFO ticket exists — no dangling crash suspects, no stranded
    tickets wedging later batches on the same regions."""
    idx = make_scope_index("triehi")
    for eid in range(8):
        idx.insert(eid, f"/t{eid % 2}/d/")
    ex = DSMExecutor(idx)
    with pytest.raises(TypeError):
        ex.apply_many([DSM("move", "/t0/d/", "/t1/"),
                       DSM("move", 5, "/t0/")], max_workers=1)
    assert ex.journal.uncommitted() == []      # nothing journaled
    assert set(idx.resolve("/t0/d/")) == {0, 2, 4, 6}   # nothing applied
    # the region queue is clean: an overlapping follow-up runs promptly
    res = ex.apply_many([DSM("move", "/t0/d/", "/t2/")], max_workers=1)
    assert res.applied == 1, res.errors
    idx.check_invariants()


def test_apply_many_records_unexpected_apply_errors():
    """An exception raised mid-apply (not a Key/ValueError rejection) is
    recorded per-op; the remaining ops still run and their tickets drain."""
    idx = make_scope_index("triehi")
    for eid in range(4):
        idx.insert(eid, f"/t{eid % 2}/d/")
    boom = RuntimeError("disk on fire")
    real_move = idx.move

    def exploding_move(src, new_parent, stats=None):
        if P.parse(src) == ("t0", "d"):
            raise boom
        return real_move(src, new_parent, stats=stats)

    idx.move = exploding_move
    ex = DSMExecutor(idx)
    res = ex.apply_many([DSM("move", "/t0/d/", "/t1/"),
                         DSM("move", "/t1/d/", "/t2/")], max_workers=1)
    assert res.errors[0] is boom
    assert res.applied == 1
    assert ex.journal.uncommitted() == []      # aborted + committed


def test_pe_strategies_still_evict_on_dsm():
    """The global-epoch strategies cannot patch; their entries must all die
    on DSM (the contrast the cache-survival benchmark measures)."""
    rng = np.random.default_rng(5)
    paths = [f"/s{t}/" for t in range(4) for _ in range(6)]
    vecs = rng.normal(size=(len(paths), 8)).astype(np.float32)
    db = DirectoryVectorDB(dim=8, scope_strategy="pe_offline")
    db.ingest(vecs, paths)
    db.build_ann("flat")
    q = rng.normal(size=(4, 8)).astype(np.float32)
    db.dsq_batch(q, ["/", "/s0/", "/s1/", "/s2/"], k=3)
    cache = db.planner().cache
    db.move("/s0/", "/s3/")
    valid, total = cache.revalidate(db.namespaces["fs"], len(db.store))
    assert total > 0 and valid == 0


# --------------------------------------------------- write amplification
def _bulk_subtree(idx, n_entries, top="/big/", eid_base=0):
    """n_entries spread over n_entries//8 leaf dirs under ``top``."""
    for i in range(n_entries):
        idx.insert(eid_base + i, f"{top}g{i % max(1, n_entries // 8)}/")


def test_write_amplification_table_ii_shape():
    """Fixed depth, growing subtree: TrieHI's structural write count stays
    flat (O(depth) ancestor chain + one relink) and re-files nothing, while
    PE-OFFLINE's grows with the subtree (key remap + per-level re-filing)."""
    sizes = (40, 320)
    touches = {}
    rewrites = {}
    for strategy in STRATEGIES:
        touches[strategy] = []
        rewrites[strategy] = []
        for n in sizes:
            idx = make_scope_index(strategy)
            idx.insert(10_000, "/dst/keep/")
            _bulk_subtree(idx, n, top="/a/b/big/")
            stats = DSMStats()
            idx.move("/a/b/big/", "/dst/", stats=stats)
            idx.check_invariants()
            touches[strategy].append(stats.write_touches)
            rewrites[strategy].append(stats.ids_rewritten)
    assert touches["triehi"][1] == touches["triehi"][0], \
        "TrieHI structural writes must not grow with subtree size"
    assert rewrites["triehi"] == [0, 0]
    assert touches["pe_offline"][1] >= 4 * touches["pe_offline"][0]
    assert rewrites["pe_offline"][1] >= 4 * rewrites["pe_offline"][0]
    # PE-OFFLINE re-files every entry once per level below the subtree root
    assert rewrites["pe_offline"][0] >= sizes[0]
    assert rewrites["pe_online"][1] >= 4 * rewrites["pe_online"][0]


def test_write_touches_grow_with_depth_for_triehi():
    depths = (3, 9)
    got = []
    for d in depths:
        idx = make_scope_index("triehi")
        chain = "/" + "/".join(f"c{i}" for i in range(d)) + "/"
        for eid in range(16):
            idx.insert(eid, chain)
        idx.mkdir("/dst/")
        stats = DSMStats()
        idx.move(chain, "/dst/", stats=stats)
        got.append(stats.write_touches)
    # vacated chain shrinks to the common root: ~depth structural writes
    assert got[1] - got[0] == depths[1] - depths[0]


# ------------------------------------------------------------- PG ingest
def test_pg_incremental_ingest_reaches_new_vectors():
    """Regression: vectors ingested after build_ann("pg") never entered the
    graph and were unreachable."""
    rng = np.random.default_rng(7)
    n, dim = 160, 16
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    paths = [f"/d{i % 4}/" for i in range(n)]
    db = DirectoryVectorDB(dim=dim, scope_strategy="triehi")
    db.ingest(vecs[:100], paths[:100])
    db.build_ann("pg", max_degree=8, ef_construction=32)
    db.ingest(vecs[100:], paths[100:])
    pg = db.executors["pg"]
    assert pg._n_nodes == n
    assert (pg._n_edges[100:n] > 0).all(), "new nodes must be linked"
    hits = sum(
        int(i in db.dsq(vecs[i], "/", k=3, executor="pg",
                        ef_search=48).ids[0].tolist())
        for i in range(100, n))
    assert hits / (n - 100) >= 0.9


def test_pg_built_empty_then_ingested():
    rng = np.random.default_rng(8)
    db = DirectoryVectorDB(dim=8)
    db.build_ann("pg", max_degree=4, ef_construction=8)
    vecs = rng.normal(size=(20, 8)).astype(np.float32)
    db.ingest(vecs, ["/x/"] * 20)
    r = db.dsq(vecs[5], "/x/", k=3, executor="pg", ef_search=16)
    assert 5 in r.ids[0].tolist()


# --------------------------------------------- injected journal write faults
# Satellite of the chaos PR: the kill-point matrix above models clean
# process death; these model *partial* failures of the journal write itself
# (short write / ENOSPC / fsync failure) at every DSM op kind, in both
# phases (BEGIN write / COMMIT write). Recovery must land bit-identical to
# the twin implied by what actually reached the disk:
#   short_write/enospc at BEGIN  -> intent not durable -> op never happened
#   fsync-fault at BEGIN         -> record IS on disk  -> rolled forward
#   any fault at COMMIT          -> mutation ran, COMMIT lost -> idempotent
from repro import faults as F  # noqa: E402


def _apply_workload(ex, ops):
    for op in ops:
        try:
            ex.apply(op)
        except (KeyError, ValueError):
            pass


@pytest.mark.parametrize("strategy", list(STRATEGIES))
@pytest.mark.parametrize("fault", ["short_write", "enospc", "fsync"])
@pytest.mark.parametrize("phase", ["begin", "commit"])
def test_recovery_under_injected_journal_faults(strategy, fault, phase,
                                                tmp_path):
    ops = _crash_workload()
    probes = ["/", "/t0/", "/t1/", "/t2/", "/t1/d0/", "/t2/d0/", "/t2/d1/",
              "/t1/new/", "/t2/d1/t0/"]
    for kill in range(len(ops)):
        jp = str(tmp_path / f"{strategy}-{fault}-{phase}-{kill}.journal")
        idx = make_scope_index(strategy)
        for eid in range(30):
            idx.insert(eid, f"/t{eid % 3}/d{eid % 2}/x{eid % 2}/"
                       if eid % 5 == 0 else f"/t{eid % 3}/d{eid % 2}/")
        ex = DSMExecutor(idx, DSMJournal(jp, fsync_on_commit=True))
        _apply_workload(ex, ops[:kill])

        seam = "journal.fsync" if fault == "fsync" else "journal.write"
        kind = "error" if fault == "fsync" else fault
        # phase targets the op's first (BEGIN) or second (COMMIT/ABORT)
        # journal interaction
        plan = F.FaultPlan().add(seam, kind=kind, after=0 if phase == "begin"
                                 else 1)
        faulted = False
        with F.FaultInjector(plan) as inj:
            try:
                ex.apply(ops[kill])
            except (KeyError, ValueError):
                pass                      # op invalid; fault may not trip
            except (F.FaultError, F.InjectedCrash, OSError):
                faulted = True
        # restart over the restored index state
        ex2 = DSMExecutor(idx, DSMJournal(jp, fsync_on_commit=True))
        ex2.recover()

        # twin: which prefix of the workload should the state reflect?
        durable = kill + 1
        if faulted and phase == "begin" and fault in ("short_write",
                                                      "enospc"):
            durable = kill               # intent never became durable
        twin = make_scope_index(strategy)
        for eid in range(30):
            twin.insert(eid, f"/t{eid % 3}/d{eid % 2}/x{eid % 2}/"
                        if eid % 5 == 0 else f"/t{eid % 3}/d{eid % 2}/")
        for op in ops[:durable]:
            try:
                op.apply(twin)
            except (KeyError, ValueError):
                pass
        for probe in probes:
            for rec in (True, False):
                got = set(idx.resolve(probe, recursive=rec)
                          .to_array().tolist())
                want = set(twin.resolve(probe, recursive=rec)
                           .to_array().tolist())
                assert got == want, (strategy, fault, phase, kill, probe,
                                     rec, inj.trips)
        assert ex2.recover() == []       # replay fully resolved


# ------------------------------------------------- compaction kill points
def _journal_with_history(jp, pending_op=True):
    j = DSMJournal(jp, auto_compact_every=0)   # no auto-compact
    s0 = j.begin(DSM("mkdir", "/a/"))
    j.commit(s0)
    s1 = j.begin(DSM("move", "/a/", "/b/"))
    j.abort(s1)
    if pending_op:
        j.begin(DSM("merge", "/a/", "/c/"))    # outstanding intent
    return j


def test_compact_crash_before_replace_recovers_from_old_journal(tmp_path):
    """Kill between writing the compaction tmp and os.replace: the old
    journal file is still the authority; the stray tmp must be cleaned on
    reopen and recovery must see the same intents as before the crash."""
    jp = str(tmp_path / "dsm.journal")
    j = _journal_with_history(jp)
    before = j.uncommitted()
    plan = F.FaultPlan().add("journal.compact.tmp", kind="crash")
    with F.FaultInjector(plan):
        with pytest.raises(F.InjectedCrash):
            j.compact()
    assert os.path.exists(jp + ".compact"), "crash left the stray tmp"

    j2 = DSMJournal(jp)                        # reopen = restart
    assert not os.path.exists(jp + ".compact"), "stale tmp cleaned"
    assert j2.uncommitted() == before
    # seqs stay monotonic past the crash
    assert j2.begin(DSM("mkdir", "/d/")) > before[-1][0]


def test_compact_crash_after_replace_recovers_from_compacted(tmp_path):
    """Kill just after os.replace: the compacted file IS the journal; a
    reopen recovers the identical intent set (plus the seq watermark)."""
    jp = str(tmp_path / "dsm.journal")
    j = _journal_with_history(jp)
    before = j.uncommitted()
    plan = F.FaultPlan().add("journal.compact.done", kind="crash")
    with F.FaultInjector(plan):
        with pytest.raises(F.InjectedCrash):
            j.compact()
    assert not os.path.exists(jp + ".compact")

    j2 = DSMJournal(jp)
    assert j2.uncommitted() == before
    assert j2.begin(DSM("mkdir", "/d/")) > before[-1][0]


def test_compact_to_empty_crash_keeps_seq_watermark(tmp_path):
    """Crash-after-replace with nothing pending: the watermark record alone
    must keep reopened seqs monotonic (the reopen-collision guard)."""
    jp = str(tmp_path / "dsm.journal")
    j = _journal_with_history(jp, pending_op=False)
    top = j._seq
    plan = F.FaultPlan().add("journal.compact.done", kind="crash")
    with F.FaultInjector(plan):
        with pytest.raises(F.InjectedCrash):
            j.compact()
    j2 = DSMJournal(jp)
    assert j2.uncommitted() == []
    assert j2.begin(DSM("mkdir", "/d/")) >= top
