"""Batched multi-scope DSQ engine: plan -> packed-mask resolve -> shared
ranking launches.

Contract under test: ``dsq_batch`` is an *optimization*, never a semantic
change — bit-identical scores/ids to per-request ``dsq`` loops across all
three scope strategies and both gather/scan plans, with repeated scopes
resolved once and scope-epoch cache entries invalidated by DSM.
"""
import numpy as np
import pytest

from repro.core import STRATEGIES, make_scope_index
from repro.core import paths as P
from repro.core.idset import RoaringBitmap
from repro.core.interface import ResolveStats
from repro.datasets import make_wiki_dir
from repro.vectordb import BatchPlanner, DirectoryVectorDB, ScopeMaskCache
from repro.vectordb.flat import GATHER_THRESHOLD


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_dir(scale=0.002, dim=32, n_queries=24, seed=7)


def _db(wiki, strategy):
    db = DirectoryVectorDB(dim=32, scope_strategy=strategy)
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    return db


def _mixed_requests(wiki, B):
    """A serving-shaped batch: repeated anchors, mixed recursive flags,
    some exclusions — exercises dedup plus both plans."""
    paths = [wiki.query_anchors[i % 6] for i in range(B)]
    paths[0] = "/"                       # broad scope -> scan plan
    rec = [bool(wiki.query_recursive[i % 6]) for i in range(B)]
    exc = [[wiki.query_anchors[3]] if i % 8 == 5 else [] for i in range(B)]
    return paths, rec, exc


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_dsq_batch_bit_identical_to_loop(strategy, wiki):
    db = _db(wiki, strategy)
    B = len(wiki.queries)
    paths, rec, exc = _mixed_requests(wiki, B)
    batch = db.dsq_batch(wiki.queries, paths, k=10, recursive=rec,
                         exclude=exc)
    plans = set()
    for i in range(B):
        r = db.dsq(wiki.queries[i], paths[i], k=10, recursive=rec[i],
                   exclude=exc[i])
        np.testing.assert_array_equal(batch[i].ids, r.ids, err_msg=str(i))
        np.testing.assert_array_equal(batch[i].scores, r.scores,
                                      err_msg=str(i))
        assert batch[i].scope_size == r.scope_size
        plans.add(batch[i].plan)
    assert {"gather", "scan"} <= plans, "batch must exercise both plans"
    acct = batch[0].batch
    assert acct.batch_size == B
    assert acct.unique_scopes < B            # repeated scopes deduped
    assert acct.launches <= acct.unique_scopes
    # all scan-plan scopes shared ONE launch
    assert acct.launches == acct.plan_groups.get("gather", 0) + (
        1 if acct.plan_groups.get("scan", 0) else 0)


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_repeated_batch_hits_scope_cache(strategy, wiki):
    db = _db(wiki, strategy)
    B = 16
    paths, rec, exc = _mixed_requests(wiki, B)
    db.dsq_batch(wiki.queries[:B], paths, k=10, recursive=rec, exclude=exc)
    again = db.dsq_batch(wiki.queries[:B], paths, k=10, recursive=rec,
                         exclude=exc)
    acct = again[0].batch
    assert acct.scope_cache_hits > 0
    # TrieHI can't cache exclusion scopes whose branch dir is missing etc.;
    # plain anchor scopes must all hit
    plain = {(P.parse(p), r) for p, r, e in zip(paths, rec, exc) if not e}
    assert acct.scope_cache_hits >= len(plain)


def _synthetic_db(strategy, n_top=6, per_dir=20, dim=16, seed=0):
    """Deterministic layout: /s0/..../s{n_top-1}/ each with ``per_dir``
    entries (one nested child dir apiece), so DSM targets always exist."""
    rng = np.random.default_rng(seed)
    paths = []
    for t in range(n_top):
        for j in range(per_dir):
            paths.append(f"/s{t}/" if j % 2 else f"/s{t}/inner/")
    vecs = rng.normal(size=(len(paths), dim)).astype(np.float32)
    db = DirectoryVectorDB(dim=dim, scope_strategy=strategy)
    db.ingest(vecs, paths)
    db.build_ann("flat")
    queries = rng.normal(size=(12, dim)).astype(np.float32)
    return db, queries


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_dsm_between_identical_batches_invalidates(strategy):
    """Acceptance: a MOVE/MERGE between two identical batches must change
    results exactly as the per-request path does — no stale masks."""
    db, queries = _synthetic_db(strategy)
    B = len(queries)
    paths = ["/s0/" if i % 2 == 0 else "/" for i in range(B)]
    before = db.dsq_batch(queries, paths, k=10)
    db.merge("/s0/", "/s1/")              # DSM between the two batches
    after = db.dsq_batch(queries, paths, k=10)
    for i in range(B):
        r = db.dsq(queries[i], paths[i], k=10)
        np.testing.assert_array_equal(after[i].ids, r.ids)
        np.testing.assert_array_equal(after[i].scores, r.scores)
        assert after[i].scope_size == r.scope_size
        if paths[i] == "/s0/":
            # the merged-away anchor resolves empty now
            assert after[i].scope_size == 0 and before[i].scope_size > 0
    # /s1/ absorbed s0's entries: a cached /s1/ mask would now be stale
    r1 = db.dsq_batch(queries[:1], ["/s1/"], k=10)
    assert r1[0].scope_size == db.dsq(queries[0], "/s1/", k=10).scope_size
    # and a MOVE as well: relocate /s2/ under /s3/
    pre = db.dsq_batch(queries, ["/s3/"] * B, k=10)
    db.move("/s2/", "/s3/")
    post = db.dsq_batch(queries, ["/s3/"] * B, k=10)
    assert post[0].scope_size > pre[0].scope_size
    for i in range(B):
        r = db.dsq(queries[i], "/s3/", k=10)
        np.testing.assert_array_equal(post[i].ids, r.ids)
        np.testing.assert_array_equal(post[i].scores, r.scores)


def test_triehi_cache_survives_unrelated_dsm():
    """Per-node epochs: DSM in one subtree must not evict cached masks for
    unrelated subtrees (the precision TrieHI buys over the global epoch)."""
    db, queries = _synthetic_db("triehi")
    db.dsq_batch(queries[:4], ["/s0/"] * 4, k=5)
    cache = db.planner().cache
    h0 = cache.hits
    db.merge("/s4/", "/s5/")              # unrelated subtree DSM
    db.dsq_batch(queries[:4], ["/s0/"] * 4, k=5)
    assert cache.hits > h0, "unrelated DSM must not evict the hot scope"
    # but the merged subtrees themselves re-resolve correctly
    r = db.dsq_batch(queries[:1], ["/s4/"], k=5)
    assert r[0].scope_size == 0
    r5 = db.dsq_batch(queries[:1], ["/s5/"], k=5)
    assert r5[0].scope_size == db.dsq(queries[0], "/s5/", k=5).scope_size


@pytest.mark.parametrize("strategy", ["triehi"])
def test_executor_params_reach_the_executor(strategy, wiki):
    """An explicit executor param (e.g. a forced plan) must be honored the
    same way the per-request path honors it, not silently dropped."""
    db = _db(wiki, strategy)
    B = 6
    paths = [wiki.query_anchors[i % 3] for i in range(B)]
    batch = db.dsq_batch(wiki.queries[:B], paths, k=10, plan="scan")
    for i in range(B):
        r = db.dsq(wiki.queries[i], paths[i], k=10, plan="scan")
        np.testing.assert_array_equal(batch[i].ids, r.ids)
        np.testing.assert_array_equal(batch[i].scores, r.scores)


def test_plan_choice_matches_flat_rule():
    planner = BatchPlanner(cache=ScopeMaskCache())
    n, k = 1000, 10
    assert planner.choose_plan(0, n, k) == "empty"
    assert planner.choose_plan(k, n, k) == "gather"
    assert planner.choose_plan(int(GATHER_THRESHOLD * n), n, k) == "gather"
    assert planner.choose_plan(int(GATHER_THRESHOLD * n) + 1, n, k) == "scan"


def test_device_popcount_matches_host():
    from repro.vectordb import device_popcount
    rng = np.random.default_rng(3)
    ids = np.nonzero(rng.random(5000) < 0.3)[0].astype(np.uint32)
    bm = RoaringBitmap(ids)
    assert device_popcount(bm.to_words(5000)) == len(ids)


# --------------------------------------------------------------------------
# cross-strategy parity of the derived/batched resolution APIs on a
# randomized tree, including post-DSM checks (satellite coverage)
# --------------------------------------------------------------------------

SEGS = ["a", "b", "c", "d", "e"]


def _random_tree_ops(rng, n_ops=120, eid_start=0):
    ops = []
    eid = eid_start
    for _ in range(n_ops):
        roll = rng.random()
        path = tuple(rng.choice(SEGS, size=rng.integers(0, 4)))
        if roll < 0.55:
            ops.append(("insert", eid, path))
            eid += 1
        elif roll < 0.7:
            ops.append(("mkdir", path))
        elif roll < 0.85:
            dst = tuple(rng.choice(SEGS, size=rng.integers(0, 3)))
            ops.append(("move", path, dst))
        else:
            dst = tuple(rng.choice(SEGS, size=rng.integers(1, 3)))
            ops.append(("merge", path, dst))
    return ops


def _apply(indexes, ops):
    for op in ops:
        outcomes = []
        for idx in indexes:
            try:
                getattr(idx, op[0])(*op[1:])
                outcomes.append("ok")
            except (KeyError, ValueError) as e:
                outcomes.append(type(e).__name__)
        assert len(set(outcomes)) == 1, (op, outcomes)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batch_and_derived_resolution_parity(seed):
    rng = np.random.default_rng(seed)
    indexes = [make_scope_index(name) for name in STRATEGIES]
    _apply(indexes, _random_tree_ops(rng))

    probes = [tuple(rng.choice(SEGS, size=rng.integers(0, 4)))
              for _ in range(12)] + [()]
    recs = [bool(rng.integers(0, 2)) for _ in probes]
    excl = [[tuple(rng.choice(SEGS, size=rng.integers(1, 3)))]
            if rng.random() < 0.4 else [] for _ in probes]

    def snapshot():
        per_strategy = []
        for idx in indexes:
            stats = ResolveStats()
            batch = idx.resolve_batch(probes, recursive=recs, exclude=excl,
                                      stats=stats)
            sets = [frozenset(int(x) for x in bm.to_array()) for bm in batch]
            # resolve_batch must agree with one-at-a-time resolution
            for p, r, e, got in zip(probes, recs, excl, sets):
                want = (idx.resolve_exclusion(p, e, recursive=r) if e
                        else idx.resolve(p, recursive=r))
                assert got == frozenset(int(x) for x in want.to_array())
            pats = [frozenset(int(x) for x in
                              idx.resolve_pattern(("*",) + p[1:]).to_array())
                    for p in probes if p]
            per_strategy.append((sets, pats))
        assert per_strategy[0] == per_strategy[1] == per_strategy[2]
        return per_strategy[0]

    snapshot()
    # post-DSM: mutate all three identically, then re-check parity; any
    # strategy holding a stale internal aggregate would diverge here
    # (eid_start continues past batch one — entry ids are never reused)
    _apply(indexes, _random_tree_ops(rng, n_ops=30, eid_start=10_000))
    for idx in indexes:
        idx.check_invariants()
    snapshot()
