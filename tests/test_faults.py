"""Fault-injection framework (repro/faults.py) + graceful degradation.

Three layers of contract:

* **Framework** — seeded determinism (same plan seed -> same trip pattern),
  exact ``after``/``count`` windows, latency-only rules, thread-safe trip
  accounting, process-global install discipline.
* **Degradation** — every injected failure surfaces as a *typed* outcome,
  never a hang or a silent wrong answer: transient host-fetch faults retry
  with backoff (bit-identical results, retries accounted), deadline misses
  shed with :class:`DeadlineExceeded` at batch formation, cancelled tickets
  free their admission slot, worker-thread death flips the scheduler to
  ``readonly`` (queued + in-flight tickets resolve with
  :class:`SchedulerUnhealthy`, submits fail fast), breaker trips walk the
  recall-clamped downshift ladder and sustained success walks back up.
* **Chaos soak** — a seeded random fault schedule over concurrent serve +
  ingest + DSM churn + online maintenance: every request resolves with a
  result or a typed error inside a bounded wall clock, crash-recovery keeps
  the store in differential parity with the pure-Python oracle, and the
  journal settles with nothing pending.
"""
import threading
import time

import numpy as np
import pytest

from repro import faults
from repro.core import paths as P
from repro.core.ops import DSMJournal
from repro.datasets import make_wiki_dir
from repro.serving.scheduler import (AdmissionError, ContinuousScheduler,
                                     DeadlineExceeded, ScheduledDSQ,
                                     SchedulerConfig, SchedulerUnhealthy)
from repro.vectordb import DirectoryVectorDB, MaintenancePolicy
from repro.vectordb.costmodel import model_of

from test_differential import PyOracle

DIM = 16
K = 5


# ---------------------------------------------------------------- framework
def _trip_pattern(plan: faults.FaultPlan, seam: str, n: int):
    """Fire ``seam`` n times under a fresh injector; True where it tripped."""
    pattern = []
    with faults.FaultInjector(plan) as inj:
        for _ in range(n):
            try:
                faults.fire(seam)
                pattern.append(False)
            except faults.FaultError:
                pattern.append(True)
    assert faults.active() is None          # uninstalled on exit
    assert inj.trips.get(seam, 0) == sum(pattern)
    return pattern


def test_after_count_window_is_exact():
    plan = faults.FaultPlan(seed=0).add("x", kind="error", after=2, count=2)
    assert _trip_pattern(plan, "x", 6) == [False, False, True, True,
                                           False, False]


def test_seeded_determinism():
    mk = lambda seed: faults.FaultPlan(seed=seed).add(
        "x", kind="error", p=0.5, count=None)
    a = _trip_pattern(mk(7), "x", 40)
    b = _trip_pattern(mk(7), "x", 40)
    c = _trip_pattern(mk(8), "x", 40)
    assert a == b                            # same seed -> same schedule
    assert a != c                            # different seed -> different
    assert 0 < sum(a) < 40                   # p=0.5 actually probabilistic


def test_latency_rule_sleeps_then_continues():
    plan = faults.FaultPlan().add("slow", kind="latency", latency_s=0.05)
    with faults.FaultInjector(plan) as inj:
        t0 = time.perf_counter()
        assert faults.fire("slow") is None   # no error raised
        assert time.perf_counter() - t0 >= 0.04
        assert faults.fire("slow") is None   # count=1: second hit clean
    assert inj.trips == {"slow": 1}


def test_enospc_is_a_real_oserror():
    import errno
    with faults.FaultInjector(faults.FaultPlan().add("j", kind="enospc")):
        with pytest.raises(OSError) as ei:
            faults.fire("j")
        assert ei.value.errno == errno.ENOSPC


def test_injected_crash_escapes_except_exception():
    assert not issubclass(faults.InjectedCrash, Exception)
    with faults.FaultInjector(faults.FaultPlan().add("c", kind="crash")):
        with pytest.raises(faults.InjectedCrash):
            try:
                faults.fire("c")
            except Exception:                # noqa: BLE001 — must NOT catch
                pytest.fail("InjectedCrash was swallowed by except Exception")


def test_nested_install_raises_and_fire_is_noop_when_uninstalled():
    assert faults.fire("anything") is None   # no injector: free no-op
    inj = faults.FaultInjector(faults.FaultPlan().add("x"))
    with inj:
        with pytest.raises(RuntimeError):
            faults.FaultInjector(faults.FaultPlan()).install()
    assert faults.active() is None


def test_thread_safe_trip_accounting():
    plan = faults.FaultPlan().add("t", kind="transient", count=7)
    tripped = []
    with faults.FaultInjector(plan) as inj:
        def worker():
            for _ in range(50):
                try:
                    faults.fire("t")
                except faults.TransientFault:
                    tripped.append(1)
        threads = [threading.Thread(target=worker) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(tripped) == 7                 # count honored across threads
    assert inj.total_trips() == 7


# ------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def wiki():
    return make_wiki_dir(scale=0.002, dim=32, n_queries=16, seed=7)


@pytest.fixture(scope="module")
def db(wiki):
    db = DirectoryVectorDB(dim=32, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=8)
    db.build_ann("pg", max_degree=8, ef_construction=16)
    db.build_ann("sharded")
    return db


def _submit_n(sched, wiki, n, **kw):
    tickets = []
    for i in range(n):
        tickets.append(sched.submit(wiki.queries[i], "/", **kw))
    return tickets


# ------------------------------------------------- host-fetch bounded retry
def test_host_fetch_transient_retry_bit_identical(db, wiki):
    q = wiki.queries[:4]
    paths = ["/"] * 4
    want = db.dsq_batch(q, paths, k=K, executor="flat", precision="int8")
    r0 = db.store.host_fetch_retries
    plan = faults.FaultPlan(seed=1).add("store.host_fetch",
                                        kind="transient", count=2)
    with faults.FaultInjector(plan) as inj:
        got = db.dsq_batch(q, paths, k=K, executor="flat", precision="int8")
    assert inj.trips == {"store.host_fetch": 2}
    assert db.store.host_fetch_retries - r0 == 2
    # retries are invisible to results AND surfaced in the accounting
    assert got[0].batch.host_fetch_retries == 2
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w.ids, g.ids)
        np.testing.assert_array_equal(w.scores, g.scores)


def test_host_fetch_retry_exhaustion_is_typed(db, wiki):
    f0 = db.store.host_fetch_failures
    plan = faults.FaultPlan().add("store.host_fetch", kind="transient",
                                  count=None)
    with faults.FaultInjector(plan):
        with pytest.raises(faults.FaultError):
            db.dsq(wiki.queries[0], "/", k=K, executor="flat",
                   precision="int8")
    assert db.store.host_fetch_failures == f0 + 1


# ------------------------------------------------------ deadlines + cancel
class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def _noop_sched(cfg, clock=None):
    return ContinuousScheduler(lambda payloads, staged: list(payloads),
                               cfg=cfg, clock=clock)


def test_deadline_exceeded_typed_shed_at_formation():
    clk = _FakeClock()
    s = _noop_sched(SchedulerConfig(max_batch=8, deadline_ms=50.0), clock=clk)
    t1 = s.submit("a")
    t2 = s.submit("b", deadline_ms=500.0)    # per-request override
    clk.t += 0.2                             # 200 ms: t1 expired, t2 alive
    assert s.pump() == 1                     # only t2 occupied a slot
    assert t2.result(0) == "b"
    with pytest.raises(DeadlineExceeded) as ei:
        t1.result(0)
    assert ei.value.deadline_ms == pytest.approx(50.0)
    assert ei.value.waited_ms == pytest.approx(200.0)
    assert s._pending == 0                   # expired slot was released
    snap = s.metrics.snapshot()
    assert snap["expired"] == 1 and snap["completed"] == 1
    assert snap["shed_rate"] == pytest.approx(0.5)


def test_cancel_frees_slot_and_is_not_counted_forever():
    s = _noop_sched(SchedulerConfig(max_batch=8, queue_capacity=2))
    t1 = s.submit("a")
    t2 = s.submit("b")
    with pytest.raises(AdmissionError):      # queue at capacity
        s.submit("c")
    assert t1.cancel() is True
    assert t1.cancel() is True               # idempotent while unresolved
    assert s.pump() == 1                     # t1 reaped, t2 served
    assert t2.result(0) == "b"
    assert t1.cancelled and not t1.done()    # abandoned, never resolved
    assert t2.cancel() is False              # too late: already resolved
    assert s._pending == 0 and s._inflight == 0
    assert s.drain(timeout=0) is True        # the leak fix: slot released
    assert s.metrics.snapshot()["cancelled"] == 1
    s.submit("d")                            # capacity available again
    assert s.pump() == 1


# ------------------------------------------------------- worker-thread death
def test_executor_thread_death_flips_readonly_and_fails_fast():
    plan = faults.FaultPlan().add("sched.execute", kind="crash")
    s = _noop_sched(SchedulerConfig(max_batch=4, max_wait_ms=1.0))
    with faults.FaultInjector(plan):
        s.start()
        t1 = s.submit("a")
        with pytest.raises(SchedulerUnhealthy):
            t1.result(5.0)                   # in-flight batch resolved typed
        assert s.health == "readonly"
        assert s.metrics.health == "readonly"
        with pytest.raises(SchedulerUnhealthy):
            s.submit("b")                    # fail fast, not queue forever
        s.stop()                             # clean join, no deadlock


def test_collector_thread_death_resolves_formed_batch():
    plan = faults.FaultPlan().add("sched.collect", kind="crash")
    s = _noop_sched(SchedulerConfig(max_batch=4, max_wait_ms=1.0))
    with faults.FaultInjector(plan):
        s.start()
        t1 = s.submit("a")
        with pytest.raises(SchedulerUnhealthy):
            t1.result(5.0)                   # batch had left the queues
        assert s.health == "readonly"
        s.stop()


# --------------------------------------------------- degradation ladder
def test_stage_fault_absorbed_bit_identical(db, wiki):
    sched = ScheduledDSQ(db, k=K, executor="flat", stage=True,
                         cfg=SchedulerConfig(max_batch=8))
    plan = faults.FaultPlan().add("sched.stage", kind="error")
    with faults.FaultInjector(plan):
        tickets = _submit_n(sched, wiki, 4)
        assert sched.pump() == 4
    got = [t.result(0) for t in tickets]
    want = db.dsq_batch(wiki.queries[:4], ["/"] * 4, k=K, executor="flat")
    assert sched.scheduler.stage_faults == 1
    assert sched.health == "healthy"         # stage faults cost perf only
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w.ids, g.ids)
        np.testing.assert_array_equal(w.scores, g.scores)


def test_breaker_downshift_then_recovery(db, wiki):
    sched = ScheduledDSQ(db, k=K, executor="sharded", precision="fp32",
                         stage=False,
                         cfg=SchedulerConfig(max_batch=4,
                                             breaker_trip_after=2,
                                             breaker_reset_after=2))
    plan = faults.FaultPlan().add("sched.execute", kind="error", count=2)
    with faults.FaultInjector(plan):
        for _ in range(2):                   # two consecutive batch failures
            (t,) = _submit_n(sched, wiki, 1)
            assert sched.pump() == 1
            with pytest.raises(faults.FaultError):
                t.result(0)
    # breaker tripped -> one rung down, recall-clamped
    assert sched.health == "degraded" and sched.degrade_level == 1
    assert sched.executor == "flat"          # sharded -> flat fallback
    assert sched.precision == "int8"
    # the rescore window is the cost model's recall-gated pick (None defers
    # to the executor's DEFAULT_RESCORE_FACTOR floor — never narrower)
    assert sched.rescore_k == model_of(db.store).pick_rescore_k(
        K, None, len(db.store))
    # degraded serving is the downshifted plan, bit-identical to direct
    tickets = _submit_n(sched, wiki, 3)
    assert sched.pump() == 3
    got = [t.result(0) for t in tickets]
    want = db.dsq_batch(wiki.queries[:3], ["/"] * 3, k=K, executor="flat",
                        precision="int8", rescore_k=sched.rescore_k)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w.ids, g.ids)
    # sustained success closes the breaker: healthy config restored
    _submit_n(sched, wiki, 1)
    assert sched.pump() == 1
    assert sched.health == "healthy" and sched.degrade_level == 0
    assert sched.executor == "sharded" and sched.precision == "fp32"
    snap = sched.metrics.snapshot()
    assert snap["degrades"] == 1 and snap["recoveries"] == 1
    assert snap["failed"] == 2


def test_sharded_h2d_fault_degrades_to_flat(db, wiki):
    sched = ScheduledDSQ(db, k=K, executor="sharded", precision="fp32",
                         stage=False,
                         cfg=SchedulerConfig(max_batch=4,
                                             breaker_trip_after=2))
    plan = faults.FaultPlan().add("sharded.h2d", kind="error", count=None)
    with faults.FaultInjector(plan):
        for _ in range(2):                   # H2D path fails every batch
            (t,) = _submit_n(sched, wiki, 1)
            sched.pump()
            with pytest.raises(faults.FaultError):
                t.result(0)
        assert sched.health == "degraded" and sched.executor == "flat"
        # flat avoids the faulting H2D seam entirely: serving continues
        tickets = _submit_n(sched, wiki, 2)
        assert sched.pump() == 2
        got = [t.result(0) for t in tickets]
    want = db.dsq_batch(wiki.queries[:2], ["/"] * 2, k=K, executor="flat",
                        precision="int8", rescore_k=sched.rescore_k)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w.ids, g.ids)


def test_downshift_param_floors_ivf_and_pg(db):
    ivf = ScheduledDSQ(db, k=K, executor="ivf", precision="int8",
                       nprobe=8, stage=False)
    floor = model_of(db.store).default_nprobe(db.executors["ivf"].n_lists)
    ivf._downshift()
    assert ivf.executor_params["nprobe"] == max(floor, 4)
    for _ in range(4):                       # ladder is floor-clamped
        ivf._downshift()
    assert ivf.executor_params["nprobe"] >= floor
    ivf._upshift()
    assert ivf.executor_params["nprobe"] == 8 and ivf.degrade_level == 0

    pg = ScheduledDSQ(db, k=K, executor="pg", precision="int8",
                      ef_search=64, stage=False)
    pg._downshift()
    assert pg.executor_params["ef_search"] == 32
    for _ in range(4):
        pg._downshift()
    assert pg.executor_params["ef_search"] >= 2 * K


# -------------------------------------------------------------- chaos soak
_SOAK_POLICY = MaintenancePolicy(
    tombstone_min=8, tombstone_fraction=0.05,
    pad_waste_min=32, pad_waste_fraction=0.10,
    repair_deletes=4, n_iters=2, sample=64)


def _recover_bounded(db, reopen):
    """Settle the journal under still-armed fault rules: recovery itself may
    trip (crash-during-recovery), so retry a bounded number of times —
    each retry consumes rule budget, so convergence is guaranteed and a
    hang is impossible."""
    ex = db._dsm["fs"]
    for _ in range(8):
        try:
            if reopen:                       # simulated restart: journal
                ex.journal = DSMJournal(     # state must come from disk
                    ex.journal.path,
                    fsync_on_commit=ex.journal.fsync_on_commit)
            return db.recover()
        except faults.InjectedCrash:
            reopen = True
        except OSError:
            reopen = False
    raise AssertionError("recovery did not converge in bounded retries")


def _churn(db, oracle, op, *args):
    """One journaled DSM op under possible injected journal faults. ENOSPC
    (an Exception) models a failed append with the process alive;
    short_write raises InjectedCrash — simulated death, so the journal
    reopens from disk. recover() then settles any durable intent and a
    ``has_dir`` probe decides whether the op landed, keeping the oracle
    in lockstep either way."""
    idx = db.namespaces["fs"]
    try:
        getattr(db, op)(*args)
    except faults.InjectedCrash:
        _recover_bounded(db, reopen=True)
    except OSError:
        _recover_bounded(db, reopen=False)
    else:
        getattr(oracle, op)(*args)
        return True
    if op == "mkdir":
        applied = idx.has_dir(args[0])
    else:                                    # move(src, new_parent)
        src, npar = P.parse(args[0]), P.parse(args[1])
        applied = idx.has_dir(npar + (src[-1],))
    if applied:
        getattr(oracle, op)(*args)
    return applied


def _maintain(db, mgr, oracle, alive):
    """One maintenance step under journal faults. Compaction application is
    detected from the store itself (row count shrinks) — robust even when
    the fault hit the COMMIT append — and rekeys the oracle through the
    order-preserving remap, exactly as the differential harness does."""
    n0 = len(db.store)
    alive_b = db.store.alive_bool()
    try:
        mgr.step()
    except faults.InjectedCrash:
        _recover_bounded(db, reopen=True)
    except OSError:
        _recover_bounded(db, reopen=False)
    if len(db.store) != n0:                  # compaction landed
        alive_rows = (np.nonzero(alive_b)[0] if alive_b is not None
                      else np.arange(n0))
        mapping = np.full(n0, -1, np.int64)
        mapping[alive_rows] = np.arange(len(alive_rows))
        oracle.entries = {int(mapping[e]): d
                          for e, d in oracle.entries.items()}
        oracle.vectors = {int(mapping[e]): v
                          for e, v in oracle.vectors.items()}
        alive[:] = [int(mapping[i]) for i in alive]
        assert all(i >= 0 for i in alive)


def _check_served(res, q, oracle, path, degraded):
    """Oracle parity for one served request: the scope is always exact;
    healthy fp32 must return the exact top-k (tie-tolerant), a degraded
    (int8, narrowed) answer must still be in-scope with true fp32 scores —
    narrower search, never a wrong one."""
    scope = oracle.resolve(path, recursive=True)
    assert res.scope_size == len(scope)
    ids = [int(i) for i in res.ids[0] if int(i) >= 0]
    scores = [float(s) for s, i in zip(res.scores[0], res.ids[0])
              if int(i) >= 0]
    assert set(ids) <= scope, set(ids) - scope
    osc = oracle.scores(q, ids)
    for i, s in zip(ids, scores):
        assert abs(osc[i] - s) < 1e-4 * max(1.0, abs(s)), (i, s, osc[i])
    if not degraded:
        want = oracle.topk(q, scope, K)
        want_ids = {i for i, _ in want}
        for miss in want_ids - set(ids):
            tie = min(scores) if scores else -np.inf
            assert abs(dict(want)[miss] - tie) < 1e-5, (miss, tie)


@pytest.mark.parametrize("seed", [3, 11])
def test_chaos_soak(seed, tmp_path):
    """Randomized fault schedule over serve + ingest + churn + maintenance:
    bounded wall clock, every ticket resolves typed, differential-oracle
    parity after every recovery, journal settles clean."""
    t_start = time.monotonic()
    rng = np.random.default_rng(seed)
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi",
                           journal_path=str(tmp_path / "soak"))
    oracle = PyOracle()
    dirs = ["/a", "/a/b", "/c", "/c/d", "/e"]
    for d in dirs:
        db.mkdir(d)
        oracle.mkdir(d)
    vecs = rng.normal(size=(160, DIM)).astype(np.float32)
    paths = [(["/"] + dirs)[int(rng.integers(6))] for _ in range(160)]
    ids = db.ingest(vecs, paths)
    oracle.ingest(ids, vecs, paths)
    alive = [int(i) for i in ids]
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=8)
    mgr = db.maintenance(policy=_SOAK_POLICY)
    sched = ScheduledDSQ(db, k=K, executor="flat", precision="fp32",
                         cfg=SchedulerConfig(max_batch=8,
                                             deadline_ms=30_000.0,
                                             breaker_trip_after=2,
                                             breaker_reset_after=2))
    plan = (faults.FaultPlan(seed=1000 + seed)
            .add("store.host_fetch", kind="transient", p=0.05, count=12)
            .add("store.host_fetch", kind="latency", p=0.03, count=8,
                 latency_s=0.001)
            .add("sched.execute", kind="error", p=0.10, count=5)
            .add("sched.stage", kind="error", p=0.05, count=3)
            .add("journal.write", kind="enospc", p=0.20, count=3)
            .add("journal.write", kind="short_write", p=0.12, count=2)
            .add("maint.apply", kind="crash", p=0.30, count=2))
    outcomes = {"ok": 0, "deadline": 0, "fault": 0}
    all_tickets = []
    mv_seq = 0
    with faults.FaultInjector(plan) as inj:
        for rnd in range(40):
            roll = rng.random()
            if roll < 0.25:                  # ingest (not journaled)
                n = int(rng.integers(1, 5))
                ds = sorted(P.to_str(d) for d in oracle.dirs)
                ps = [ds[int(rng.integers(len(ds)))] for _ in range(n)]
                vs = rng.normal(size=(n, DIM)).astype(np.float32)
                new = db.ingest(vs, ps)
                oracle.ingest(new, vs, ps)
                alive.extend(int(i) for i in new)
            elif roll < 0.40 and alive:      # delete (not journaled)
                eid = alive.pop(int(rng.integers(len(alive))))
                db.delete(eid)
                oracle.delete(eid)
            elif roll < 0.55:                # journaled churn under faults
                mv_seq += 1
                made = _churn(db, oracle, "mkdir", f"/e/m{mv_seq}")
                if made and rng.random() < 0.5:
                    _churn(db, oracle, "move", f"/e/m{mv_seq}", "/c")
            elif roll < 0.70:                # maintenance under faults
                _maintain(db, mgr, oracle, alive)
            # serve: submit a few queries (one with an already-spent
            # budget — must shed typed, not hang), pump, settle tickets
            batch = []
            for i in range(int(rng.integers(1, 4))):
                q = rng.normal(size=DIM).astype(np.float32)
                ds = sorted(P.to_str(d) for d in oracle.dirs)
                path = ds[int(rng.integers(len(ds)))]
                dl = 0.0 if (rnd % 10 == 5 and i == 0) else None
                batch.append((sched.submit(q, path, deadline_ms=dl), q, path))
            # the batch executes under the configuration armed *before* this
            # pump (execute snapshots it); an upshift landing mid-pump would
            # otherwise mislabel a degraded answer as exact
            was_degraded = sched.degrade_level > 0
            sched.pump()
            all_tickets.extend(t for t, _, _ in batch)
            for t, q, path in batch:
                try:
                    res = t.result(timeout=30.0)
                except DeadlineExceeded:
                    outcomes["deadline"] += 1
                except faults.FaultError:    # includes TransientFault
                    outcomes["fault"] += 1
                else:
                    _check_served(res, q, oracle, path,
                                  degraded=was_degraded)
                    outcomes["ok"] += 1
        while sched.scheduler._pending:      # drain the tail
            sched.pump()
        # ---- post-chaos invariants -------------------------------------
        assert inj.total_trips() > 0         # the chaos actually happened
    assert all(t.done() or t.cancelled for t in all_tickets)
    assert outcomes["ok"] > 20
    assert outcomes["deadline"] >= 1         # forced zero-budget submits shed
    snap = sched.metrics.snapshot()
    assert snap["shed_rate"] <= 0.5
    # journal settles: nothing pending live, nothing replayed on a clean
    # reopen, and reopening twice reads back the identical record stream
    assert mgr.stats()["journal_pending"] == 0
    assert db.recover() == {"fs": []}
    db.check_invariants()
    jpath = db._dsm["fs"].journal.path
    j1, j2 = DSMJournal(jpath), DSMJournal(jpath)
    assert j1.uncommitted() == [] and j2.uncommitted() == []
    assert j1._seq == j2._seq == db._dsm["fs"].journal._seq
    # differential parity after all recoveries: every directory scope
    # resolves to exactly the oracle's entry set
    idx = db.namespaces["fs"]
    for d in sorted(oracle.dirs):
        got = {int(i) for i in idx.resolve(d, recursive=True).to_array()}
        assert got == oracle.resolve(P.to_str(d), recursive=True), d
    assert time.monotonic() - t_start < 120.0    # bounded wall clock
