"""RoaringBitmap: property tests against Python sets (the obvious oracle)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.idset import ARRAY_MAX, RoaringBitmap

ids = st.lists(st.integers(0, 1 << 20), max_size=300)


@settings(max_examples=50, deadline=None)
@given(ids, ids)
def test_set_algebra_matches_python_sets(a, b):
    ra, rb = RoaringBitmap(a), RoaringBitmap(b)
    sa, sb = set(a), set(b)
    assert set(ra.to_array().tolist()) == sa
    assert set((ra | rb).to_array().tolist()) == sa | sb
    assert set((ra & rb).to_array().tolist()) == sa & sb
    assert set((ra - rb).to_array().tolist()) == sa - sb
    assert len(ra) == len(sa)
    for x in list(sa)[:10]:
        assert x in ra


@settings(max_examples=30, deadline=None)
@given(ids, ids)
def test_inplace_ops(a, b):
    ra, rb = RoaringBitmap(a), RoaringBitmap(b)
    sa, sb = set(a), set(b)
    ra |= rb
    assert set(ra.to_array().tolist()) == sa | sb
    ra -= rb
    assert set(ra.to_array().tolist()) == (sa | sb) - sb


@settings(max_examples=30, deadline=None)
@given(ids, st.lists(st.integers(0, 1 << 20), max_size=50))
def test_remove(a, rm):
    ra = RoaringBitmap(a)
    ra.remove_many(np.asarray(rm, np.uint32))
    assert set(ra.to_array().tolist()) == set(a) - set(rm)


def test_container_promotion_and_demotion():
    # force a dense container (> ARRAY_MAX within one 64k chunk)
    ids = np.arange(ARRAY_MAX + 100, dtype=np.uint32)
    r = RoaringBitmap.from_array(ids)
    assert r.stats()["bitmap_containers"] == 1
    # difference that drops it back below the threshold
    r -= RoaringBitmap.from_array(ids[: ARRAY_MAX])
    assert len(r) == 100
    assert set(r.to_array().tolist()) == set(range(ARRAY_MAX, ARRAY_MAX + 100))


def test_dense_mask_and_words():
    ids = [0, 5, 31, 32, 63, 1000]
    r = RoaringBitmap(ids)
    mask = r.to_bool_mask(1024)
    assert sorted(np.nonzero(mask)[0].tolist()) == sorted(set(ids))
    words = r.to_words(1024)
    assert words.dtype == np.uint32
    unpacked = np.unpackbits(words.view(np.uint8), bitorder="little")
    assert sorted(np.nonzero(unpacked)[0].tolist()) == sorted(set(ids))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1 << 18), max_size=300),
       st.integers(0, (1 << 18) + 40))
def test_to_words_fast_path_matches_packbits(a, n):
    """The container-direct word emitter must be bit-identical to the
    dense-mask + packbits roundtrip it replaced, for any export length."""
    r = RoaringBitmap(a)
    padded = ((n + 31) // 32) * 32
    mask = np.zeros(padded, dtype=bool)
    keep = np.asarray([x for x in set(a) if x < padded], dtype=np.int64)
    mask[keep] = True
    want = np.packbits(mask, bitorder="little").view(np.uint32)
    assert np.array_equal(r.to_words(n), want)
    bmask = r.to_bool_mask(n)
    assert bmask.dtype == bool and bmask.shape == (n,)
    assert np.array_equal(bmask, mask[:n])


def test_to_words_dense_container_fast_path():
    """A bitmap container (> ARRAY_MAX dense ids) is emitted by direct word
    copy; spot-check both container kinds in one set."""
    dense = np.arange(ARRAY_MAX + 200, dtype=np.uint32)        # bitmap
    sparse = np.asarray([70000, 70003, 200000], np.uint32)     # arrays
    r = RoaringBitmap(np.concatenate([dense, sparse]))
    n = 200001
    words = r.to_words(n)
    got = np.nonzero(np.unpackbits(words.view(np.uint8),
                                   bitorder="little")[:n])[0]
    assert np.array_equal(got, np.sort(np.concatenate([dense, sparse])))


def test_union_many_and_copy_isolation():
    parts = [RoaringBitmap(range(i, i + 10)) for i in range(0, 100, 10)]
    u = RoaringBitmap.union_many(parts)
    assert len(u) == 100
    c = u.copy()
    c.remove(0)
    assert 0 in u and 0 not in c


def test_equality_and_empty():
    assert RoaringBitmap([1, 2]) == RoaringBitmap([2, 1])
    assert not RoaringBitmap()
    assert len(RoaringBitmap()) == 0
    assert RoaringBitmap().to_array().shape == (0,)
