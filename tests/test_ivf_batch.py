"""Device-resident batched IVF/PG executors.

Contract under test: the fused batched paths are *optimizations*, never
semantic changes — ``dsq_batch(executor="ivf"/"pg")`` is bit-identical to the
per-request ``dsq`` loop, the device IVF path matches the per-query host-loop
oracle, scoped recall holds against flat ground truth, DSM invalidates cached
scope masks on the IVF path, and tombstoned rows never surface from partition
lists or graph result sets.
"""
import numpy as np
import pytest

from repro.datasets import make_wiki_dir
from repro.vectordb import DirectoryVectorDB

DIM = 32


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_dir(scale=0.0015, dim=DIM, n_queries=12, seed=5)


@pytest.fixture(scope="module")
def db(wiki):
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=16)
    db.build_ann("pg", max_degree=10, ef_construction=24)
    return db


def _mixed(wiki, B):
    paths = [wiki.query_anchors[i % 4] for i in range(B)]
    paths[0] = "/"                              # one broad scope in the mix
    rec = [bool(wiki.query_recursive[i % 4]) for i in range(B)]
    return paths, rec


def _same_topk(ids_a, scores_a, ids_b, scores_b, msg=""):
    """Same member set per request + matching finite scores (tie order and
    numpy-vs-jnp low bits may differ between implementations)."""
    for b in range(ids_a.shape[0]):
        assert (set(ids_a[b][ids_a[b] >= 0].tolist())
                == set(ids_b[b][ids_b[b] >= 0].tolist())), (msg, b)
        np.testing.assert_allclose(
            np.sort(scores_a[b][np.isfinite(scores_a[b])]),
            np.sort(scores_b[b][np.isfinite(scores_b[b])]),
            rtol=1e-4, atol=1e-4, err_msg=f"{msg} {b}")


def test_batched_ivf_matches_loop_oracle(wiki, db):
    """Single-launch device path vs the per-query host-loop oracle: same ids
    and scores per request, scoped and unscoped."""
    ivf = db.executors["ivf"]
    q = wiki.queries.astype(np.float32)
    s1, i1 = ivf.search(q, 10, nprobe=6)
    s2, i2 = ivf.search_loop(q, 10, nprobe=6)
    _same_topk(i1, s1, i2, s2, "unscoped")
    cand = np.arange(0, len(db.store), 3, dtype=np.uint32)
    s1, i1 = ivf.search(q, 10, candidate_ids=cand, nprobe=6)
    s2, i2 = ivf.search_loop(q, 10, candidate_ids=cand, nprobe=6)
    _same_topk(i1, s1, i2, s2, "scoped")
    assert (i1[i1 >= 0] % 3 == 0).all()         # scope respected


def test_pallas_kernel_matches_jnp_twin(wiki, db):
    ivf = db.executors["ivf"]
    q = wiki.queries.astype(np.float32)
    n = len(db.store)
    mask = np.zeros(((n + 31) // 32) * 32, dtype=bool)
    mask[np.arange(0, n, 2)] = True
    words = np.packbits(mask, bitorder="little").view(np.uint32)[None, :]
    sids = np.zeros(len(q), np.int32)
    sa, ia = ivf.search_multi(q, words, sids, 10, nprobe=6, use_pallas=False)
    sb, ib = ivf.search_multi(q, words, sids, 10, nprobe=6, use_pallas=True)
    _same_topk(ia, sa, ib, sb, "pallas")


@pytest.mark.parametrize("executor,params", [
    ("ivf", {"nprobe": 6}), ("ivf", {}), ("pg", {"ef_search": 32}),
    ("pg", {}),
])
def test_dsq_batch_equals_looped_dsq(wiki, db, executor, params):
    """Acceptance: dsq_batch matches the per-request dsq loop for both
    non-flat executors (default and plannable-param calls) — PG bit-identical,
    IVF same members/scores (batched dot_general low bits may differ with
    batch shape) — with one shared IVF launch and one PG traversal-mask build
    per unique scope."""
    B = len(wiki.queries)
    paths, rec = _mixed(wiki, B)
    batch = db.dsq_batch(wiki.queries, paths, k=10, recursive=rec,
                         executor=executor, **params)
    for i in range(B):
        r = db.dsq(wiki.queries[i], paths[i], k=10, recursive=rec[i],
                   executor=executor, **params)
        if executor == "pg":
            np.testing.assert_array_equal(batch[i].ids, r.ids,
                                          err_msg=str(i))
            np.testing.assert_array_equal(batch[i].scores, r.scores,
                                          err_msg=str(i))
        else:
            _same_topk(batch[i].ids, batch[i].scores, r.ids, r.scores,
                       f"req {i}")
        assert batch[i].scope_size == r.scope_size
        assert batch[i].plan == (executor if batch[i].scope_size else "empty")
    acct = batch[0].batch
    assert acct.batch_size == B
    assert acct.unique_scopes < B               # repeated scopes deduped
    if executor == "ivf":
        assert acct.launches == 1               # ONE fused launch, whole batch
    else:
        assert acct.launches == acct.unique_scopes


def test_dsq_batch_ivf_per_request_nprobe(wiki, db):
    """A per-request nprobe sequence groups launches by value and matches the
    loop with the respective nprobe."""
    B = 8
    paths, rec = _mixed(wiki, B)
    npr = [4] * 4 + [8] * 4
    batch = db.dsq_batch(wiki.queries[:B], paths, k=10, recursive=rec,
                         executor="ivf", nprobe=npr)
    assert batch[0].batch.launches == 2         # one per distinct nprobe
    for i in range(B):
        r = db.dsq(wiki.queries[i], paths[i], k=10, recursive=rec[i],
                   executor="ivf", nprobe=npr[i])
        _same_topk(batch[i].ids, batch[i].scores, r.ids, r.scores, str(i))


def test_dsq_batch_unplannable_params_still_fall_back(wiki, db):
    """An executor param the planner cannot plan must reach the executor via
    the per-request fallback, not be dropped."""
    with pytest.raises(TypeError):
        db.dsq_batch(wiki.queries[:2], ["/", "/"], k=5, executor="ivf",
                     bogus_param=1)


def test_scoped_ivf_recall_floor_vs_flat(wiki, db):
    """Batched IVF under directory scoping keeps recall vs the exact flat
    path on the dirgen dataset."""
    recalls = []
    for qi in range(len(wiki.queries)):
        exact = db.dsq(wiki.queries[qi], wiki.query_anchors[qi], k=10,
                       recursive=bool(wiki.query_recursive[qi]))
        want = set(exact.ids[0][exact.ids[0] >= 0].tolist())
        if not want:
            continue
        r = db.dsq(wiki.queries[qi], wiki.query_anchors[qi], k=10,
                   recursive=bool(wiki.query_recursive[qi]),
                   executor="ivf", nprobe=12)
        got = set(r.ids[0][r.ids[0] >= 0].tolist())
        recalls.append(len(got & want) / len(want))
    assert np.mean(recalls) >= 0.6, np.mean(recalls)


def _synthetic_db(n_top=5, per_dir=16, dim=16, seed=0):
    rng = np.random.default_rng(seed)
    paths = []
    for t in range(n_top):
        for j in range(per_dir):
            paths.append(f"/s{t}/" if j % 2 else f"/s{t}/inner/")
    vecs = rng.normal(size=(len(paths), dim)).astype(np.float32)
    db = DirectoryVectorDB(dim=dim, scope_strategy="triehi")
    db.ingest(vecs, paths)
    db.build_ann("ivf", n_lists=8)
    queries = rng.normal(size=(8, dim)).astype(np.float32)
    return db, queries


def test_ivf_cache_invalidation_after_move_merge():
    """Acceptance: DSM between identical batches must re-resolve on the IVF
    path exactly like per-request dsq — no stale cached masks."""
    db, queries = _synthetic_db()
    B = len(queries)
    paths = ["/s0/" if i % 2 == 0 else "/" for i in range(B)]
    before = db.dsq_batch(queries, paths, k=8, executor="ivf", nprobe=4)
    db.merge("/s0/", "/s1/")
    after = db.dsq_batch(queries, paths, k=8, executor="ivf", nprobe=4)
    for i in range(B):
        r = db.dsq(queries[i], paths[i], k=8, executor="ivf", nprobe=4)
        _same_topk(after[i].ids, after[i].scores, r.ids, r.scores, str(i))
        assert after[i].scope_size == r.scope_size
        if paths[i] == "/s0/":
            assert after[i].scope_size == 0 and before[i].scope_size > 0
    db.move("/s2/", "/s3/")
    post = db.dsq_batch(queries, ["/s3/"] * B, k=8, executor="ivf", nprobe=4)
    for i in range(B):
        r = db.dsq(queries[i], "/s3/", k=8, executor="ivf", nprobe=4)
        _same_topk(post[i].ids, post[i].scores, r.ids, r.scores, str(i))


def test_tombstones_mask_deleted_from_ivf_and_pg(wiki):
    """Deleted entries must never surface from IVF partition lists or PG
    result sets — including *unscoped* executor-level searches."""
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("ivf", n_lists=16)
    db.build_ann("pg", max_degree=10, ef_construction=24)
    q = wiki.queries[:4].astype(np.float32)
    _, ids0 = db.executors["ivf"].search(q, 10, nprobe=8)
    victims = [int(x) for x in ids0[0][ids0[0] >= 0][:3]]
    for v in victims:
        db.delete(v)
    assert db.store.n_deleted == len(victims)
    for name in ("ivf", "pg"):
        _, ids = db.executors[name].search(q, 10)      # unscoped probe
        assert not (set(victims) & set(ids.flatten().tolist())), name
    # batched DSQ path masks them too
    batch = db.dsq_batch(q, ["/"] * len(q), k=10, executor="ivf")
    got = {int(x) for r in batch for x in r.ids.flatten() if x >= 0}
    assert not (set(victims) & got)
    # oracle agrees
    _, ids = db.executors["ivf"].search_loop(q, 10)
    assert not (set(victims) & set(ids.flatten().tolist()))


def test_ivf_add_amortized_growth_keeps_membership(wiki):
    """Repeated small ingests route rows into capacity-grown lists without
    per-call concatenation; membership and search stay correct."""
    db = DirectoryVectorDB(dim=DIM)
    n0 = wiki.n_entries // 4
    db.ingest(wiki.vectors[:n0], wiki.entry_paths[:n0])
    db.build_ann("ivf", n_lists=8)
    step = max(1, (wiki.n_entries - n0) // 7)
    for lo in range(n0, wiki.n_entries, step):
        hi = min(lo + step, wiki.n_entries)
        db.ingest(wiki.vectors[lo:hi], wiki.entry_paths[lo:hi])
    ivf = db.executors["ivf"]
    members = np.sort(np.concatenate(ivf.lists))
    assert np.array_equal(members, np.arange(wiki.n_entries, dtype=np.uint32))
    r = db.dsq(wiki.queries[0], "/", k=10, executor="ivf", nprobe=8)
    assert (r.ids[0] >= 0).sum() == 10
    # layout rebuilt lazily after adds: sentinel must track the store size
    assert ivf.layout().n == len(db.store)
