"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q,n,d,k,metric,dtype", [
    (1, 128, 32, 4, "ip", np.float32),
    (3, 1000, 64, 10, "ip", np.float32),
    (8, 4096, 128, 10, "l2", np.float32),
    (5, 2048, 256, 16, "l2", np.float32),
    (2, 777, 128, 8, "ip", jnp.bfloat16),
    (16, 512, 512, 32, "ip", np.float32),
])
def test_scoped_topk_sweep(q, n, d, k, metric, dtype):
    Q = RNG.normal(size=(q, d)).astype(np.float32)
    X = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32), dtype=dtype)
    mask = RNG.random(n) < 0.4
    v1, i1 = ops.scoped_topk(Q, X, mask, k=k, metric=metric)
    v2, i2 = ref.scoped_topk_ref(jnp.asarray(Q), X, jnp.asarray(mask),
                                 k=k, metric=metric)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=tol, atol=tol)
    # ids must point at valid candidates with matching scores
    for qi in range(q):
        for slot in range(k):
            idx = int(i1[qi, slot])
            if idx >= 0:
                assert mask[idx]


@pytest.mark.parametrize("q,n,d,k,metric,n_scopes", [
    (1, 128, 32, 4, "ip", 1),
    (5, 1000, 64, 10, "ip", 3),
    (8, 777, 128, 7, "l2", 4),
    (16, 2048, 256, 16, "l2", 5),
])
def test_multi_scope_topk_sweep(q, n, d, k, metric, n_scopes):
    """Single-launch heterogeneous batch: per-query scope-id indirection into
    a packed (n_scopes, n/32) mask matrix must match the unfused oracle."""
    Q = RNG.normal(size=(q, d)).astype(np.float32)
    X = RNG.normal(size=(n, d)).astype(np.float32)
    dense = RNG.random((n_scopes, n)) < 0.4
    pad = (-n) % 32
    words = np.stack([
        np.packbits(np.pad(m, (0, pad)), bitorder="little").view(np.uint32)
        for m in dense])
    sid = RNG.integers(0, n_scopes, size=q).astype(np.int32)
    v1, i1 = ops.multi_scope_topk(Q, X, words, sid, k=k, metric=metric)
    v2, i2 = ref.multi_scope_topk_ref(jnp.asarray(Q), jnp.asarray(X),
                                      jnp.asarray(words), jnp.asarray(sid),
                                      k=k, metric=metric)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-4)
    for qi in range(q):
        for slot in range(k):
            idx = int(i1[qi, slot])
            if idx >= 0:
                assert dense[sid[qi], idx], (qi, slot, idx)


def test_multi_scope_topk_degenerates_to_scoped_topk():
    """With one scope shared by every query, the multi-scope kernel must
    reproduce the single-scope kernel exactly."""
    Q = RNG.normal(size=(4, 64)).astype(np.float32)
    X = RNG.normal(size=(512, 64)).astype(np.float32)
    mask = RNG.random(512) < 0.3
    words = np.packbits(mask, bitorder="little").view(np.uint32)[None, :]
    sid = np.zeros(4, np.int32)
    v1, i1 = ops.multi_scope_topk(Q, X, words, sid, k=8)
    v2, i2 = ops.scoped_topk(Q, X, mask, k=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_multi_scope_topk_empty_scope_row():
    """A scope with zero candidates yields all -1 ids for its queries while
    other scopes in the same launch are unaffected."""
    Q = RNG.normal(size=(2, 32)).astype(np.float32)
    X = RNG.normal(size=(256, 32)).astype(np.float32)
    full = np.ones(256, bool)
    words = np.stack([
        np.zeros(8, np.uint32),
        np.packbits(full, bitorder="little").view(np.uint32)])
    sid = np.array([0, 1], np.int32)
    v, i = ops.multi_scope_topk(Q, X, words, sid, k=4)
    assert (np.asarray(i)[0] == -1).all()
    assert (np.asarray(i)[1] >= 0).all()


def _quantize(rows):
    from repro.vectordb.quant import quantize_rows
    return quantize_rows(rows)


def _q_norms(codes, scales):
    c = codes.astype(np.int32)
    return np.einsum("nd,nd->n", c, c).astype(np.float32) * scales * scales


@pytest.mark.parametrize("q,n,d,k,metric,block_q,block_n", [
    (1, 128, 32, 4, "ip", 8, 1024),
    (3, 1000, 64, 10, "ip", 8, 1024),
    (8, 4096, 128, 10, "l2", 8, 1024),
    (5, 2048, 256, 16, "l2", 4, 512),
    (16, 512, 512, 32, "ip", 8, 128),
    (2, 777, 128, 1, "ip", 2, 256),
])
def test_scoped_topk_i8_sweep(q, n, d, k, metric, block_q, block_n):
    """int8 scan kernel vs the numpy oracle across block shapes and k: the
    int32-accumulated code dot with merge-time scales must match the oracle
    bitwise on scores (both compute the identical fp32 products)."""
    Q = RNG.normal(size=(q, d)).astype(np.float32)
    X = RNG.normal(size=(n, d)).astype(np.float32)
    q_i8, q_s = _quantize(Q)
    x_i8, x_s = _quantize(X)
    sq = _q_norms(x_i8, x_s)
    mask = RNG.random(n) < 0.4
    v1, i1 = ops.scoped_topk_i8(q_i8, q_s, x_i8, x_s, sq, mask, k=k,
                                metric=metric, block_q=block_q,
                                block_n=block_n)
    v2, i2 = ref.scoped_topk_i8_ref(q_i8, q_s, x_i8, x_s, sq, mask, k=k,
                                    metric=metric)
    np.testing.assert_allclose(np.asarray(v1), v2, rtol=1e-6, atol=1e-6)
    for qi in range(q):
        for slot in range(k):
            idx = int(i1[qi, slot])
            if idx >= 0:
                assert mask[idx], (qi, slot, idx)
            else:
                assert v2[qi, slot] <= ref.NEG_INF


@pytest.mark.parametrize("q,n,d,k,metric,n_scopes", [
    (1, 128, 32, 4, "ip", 1),
    (5, 1000, 64, 10, "ip", 3),
    (8, 777, 128, 7, "l2", 4),
    (16, 2048, 256, 16, "l2", 5),
])
def test_multi_scope_topk_i8_sweep(q, n, d, k, metric, n_scopes):
    """Heterogeneous-batch int8 kernel vs the numpy oracle: packed-word
    scope indirection over the quantized store."""
    Q = RNG.normal(size=(q, d)).astype(np.float32)
    X = RNG.normal(size=(n, d)).astype(np.float32)
    q_i8, q_s = _quantize(Q)
    x_i8, x_s = _quantize(X)
    sq = _q_norms(x_i8, x_s)
    dense = RNG.random((n_scopes, n)) < 0.4
    pad = (-n) % 32
    words = np.stack([
        np.packbits(np.pad(m, (0, pad)), bitorder="little").view(np.uint32)
        for m in dense])
    sid = RNG.integers(0, n_scopes, size=q).astype(np.int32)
    v1, i1 = ops.multi_scope_topk_i8(q_i8, q_s, x_i8, x_s, sq, words, sid,
                                     k=k, metric=metric)
    v2, i2 = ref.multi_scope_topk_i8_ref(q_i8, q_s, x_i8, x_s, sq, words,
                                         sid, k=k, metric=metric)
    np.testing.assert_allclose(np.asarray(v1), v2, rtol=1e-6, atol=1e-6)
    for qi in range(q):
        for slot in range(k):
            idx = int(i1[qi, slot])
            if idx >= 0:
                assert dense[sid[qi], idx], (qi, slot, idx)


def test_multi_scope_topk_i8_empty_scope_row():
    """A scope with zero candidates yields all -1 for its queries while
    other scopes in the same int8 launch are unaffected."""
    Q = RNG.normal(size=(2, 32)).astype(np.float32)
    X = RNG.normal(size=(256, 32)).astype(np.float32)
    q_i8, q_s = _quantize(Q)
    x_i8, x_s = _quantize(X)
    sq = _q_norms(x_i8, x_s)
    words = np.stack([
        np.zeros(8, np.uint32),
        np.packbits(np.ones(256, bool), bitorder="little").view(np.uint32)])
    sid = np.array([0, 1], np.int32)
    v, i = ops.multi_scope_topk_i8(q_i8, q_s, x_i8, x_s, sq, words, sid, k=4)
    assert (np.asarray(i)[0] == -1).all()
    assert (np.asarray(i)[1] >= 0).all()


def test_scoped_topk_i8_all_masked_tiles():
    """Whole blocks masked out (and the fully-empty mask) never surface a
    candidate — the merge must ignore all-masked tiles entirely."""
    Q = RNG.normal(size=(2, 64)).astype(np.float32)
    X = RNG.normal(size=(1024, 64)).astype(np.float32)
    q_i8, q_s = _quantize(Q)
    x_i8, x_s = _quantize(X)
    sq = _q_norms(x_i8, x_s)
    v, i = ops.scoped_topk_i8(q_i8, q_s, x_i8, x_s, sq,
                              np.zeros(1024, bool), k=4, block_n=256)
    assert (np.asarray(i) == -1).all()
    # only the last block carries candidates: ids must all land there
    mask = np.zeros(1024, bool)
    mask[768:] = True
    v, i = ops.scoped_topk_i8(q_i8, q_s, x_i8, x_s, sq, mask, k=8,
                              block_n=256)
    i = np.asarray(i)
    assert (i >= 768).all()
    v2, i2 = ref.scoped_topk_i8_ref(q_i8, q_s, x_i8, x_s, sq, mask, k=8)
    np.testing.assert_allclose(np.asarray(v), v2, rtol=1e-6, atol=1e-6)


def test_scoped_topk_i8_matches_fp32_ranking():
    """The int8 scan's top-k set approximates the fp32 kernel's: with a
    4x-rescore-sized k every fp32 top-k member must appear (the recall
    contract the two-phase plan relies on)."""
    Q = RNG.normal(size=(4, 64)).astype(np.float32)
    X = RNG.normal(size=(2048, 64)).astype(np.float32)
    q_i8, q_s = _quantize(Q)
    x_i8, x_s = _quantize(X)
    sq = _q_norms(x_i8, x_s)
    mask = np.ones(2048, bool)
    vf, idf = ops.scoped_topk(Q, X, mask, k=10)
    v8, id8 = ops.scoped_topk_i8(q_i8, q_s, x_i8, x_s, sq, mask, k=40)
    idf, id8 = np.asarray(idf), np.asarray(id8)
    for qi in range(4):
        assert set(idf[qi].tolist()) <= set(id8[qi].tolist())


@pytest.mark.parametrize("b,c,d,k,metric,density", [
    (1, 128, 32, 4, "ip", 0.5),
    (4, 640, 64, 10, "ip", 0.3),
    (3, 1024, 128, 8, "l2", 0.7),
    (8, 333, 64, 16, "l2", 0.2),
])
def test_ivf_gather_topk_sweep(b, c, d, k, metric, density):
    """Batched-IVF back half: gathered candidate tiles + explicit ids +
    per-query packed scope words must match the unfused numpy oracle."""
    n = 4 * c
    X = RNG.normal(size=(n, d)).astype(np.float32)
    Q = RNG.normal(size=(b, d)).astype(np.float32)
    cand = RNG.integers(0, n, size=(b, c)).astype(np.int32)
    cand[RNG.random((b, c)) < 0.1] = -1              # CSR padding slots
    rows = X[np.maximum(cand, 0)]
    dense = RNG.random((b, n)) < density
    pad = (-n) % 32
    qwords = np.stack([
        np.packbits(np.pad(m, (0, pad)), bitorder="little").view(np.uint32)
        for m in dense])
    v1, i1 = ops.ivf_gather_topk(Q, rows, cand, qwords, k=k, metric=metric)
    v2, i2 = ref.ivf_gather_topk_ref(Q, rows, cand, qwords, k=k,
                                     metric=metric)
    v1, i1 = np.asarray(v1), np.asarray(i1)
    for qi in range(b):
        got = set(i1[qi][i1[qi] >= 0].tolist())
        want = set(i2[qi][i2[qi] >= 0].tolist())
        # duplicate candidate ids can make member sets differ on ties; the
        # sweep draws ids with replacement, so compare scores exactly and
        # membership modulo duplicates
        np.testing.assert_allclose(
            np.sort(v1[qi][i1[qi] >= 0]), np.sort(v2[qi][i2[qi] >= 0]),
            rtol=1e-4, atol=1e-4)
        for idx in got:
            assert cand[qi][(cand[qi] == idx)].size and dense[qi, idx]


def test_ivf_gather_topk_all_padding_row():
    """A query whose candidate tile is pure CSR padding yields all -1."""
    Q = RNG.normal(size=(2, 32)).astype(np.float32)
    X = RNG.normal(size=(64, 32)).astype(np.float32)
    cand = np.stack([np.full(64, -1, np.int32),
                     np.arange(64, dtype=np.int32)])
    rows = X[np.maximum(cand, 0)]
    qwords = np.tile(np.full(2, 0xFFFFFFFF, np.uint32)[None, :], (2, 1))
    v, i = ops.ivf_gather_topk(Q, rows, cand, qwords, k=4)
    assert (np.asarray(i)[0] == -1).all()
    assert (np.asarray(i)[1] >= 0).all()


def test_scoped_topk_empty_and_full_mask():
    Q = RNG.normal(size=(2, 64)).astype(np.float32)
    X = RNG.normal(size=(256, 64)).astype(np.float32)
    v, i = ops.scoped_topk(Q, X, np.zeros(256, bool), k=4)
    assert (np.asarray(i) == -1).all()
    v, i = ops.scoped_topk(Q, X, np.ones(256, bool), k=4)
    vr, ir = ref.scoped_topk_ref(jnp.asarray(Q), jnp.asarray(X),
                                 jnp.ones(256, bool), k=4)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4000), st.integers(0, 2 ** 32 - 1))
def test_bitmap_popcount_property(n, seed):
    r = np.random.default_rng(seed)
    a = r.integers(0, 2 ** 32, size=n, dtype=np.uint32)
    b = r.integers(0, 2 ** 32, size=n, dtype=np.uint32)
    w1, c1 = ops.mask_and_popcount(a, b)
    w2, c2 = ref.mask_and_popcount_ref(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(np.asarray(w1), np.asarray(w2))
    assert int(c1) == int(c2)
    # oracle-of-oracle: numpy bit_count
    assert int(c1) == int(np.bitwise_count(a & b).sum())


@pytest.mark.parametrize("rows,n_words", [
    (1, 8), (4, 64), (7, 333), (16, 2048), (3, 4097),
])
def test_bitmap_patch_sweep(rows, n_words):
    """Batched mask patch (the DSM delta-maintenance primitive): Pallas
    kernel vs jnp twin vs numpy oracle, mixed OR/AND-NOT/noop rows."""
    r = np.random.default_rng(rows * 1000 + n_words)
    masks = r.integers(0, 2 ** 32, size=(rows, n_words), dtype=np.uint32)
    delta = r.integers(0, 2 ** 32, size=n_words, dtype=np.uint32)
    signs = r.integers(-1, 2, size=rows).astype(np.int32)
    got = np.asarray(ops.bitmap_patch(masks, delta, signs))
    twin = np.asarray(ref.bitmap_patch_ref(jnp.asarray(masks),
                                           jnp.asarray(delta),
                                           jnp.asarray(signs)))
    oracle = ref.bitmap_patch_np(masks, delta, signs)
    assert np.array_equal(got, oracle)
    assert np.array_equal(twin, oracle)
    # semantic spot checks: OR rows superset delta, AND-NOT rows disjoint
    assert np.all((got[signs > 0] & delta) == delta)
    assert not np.any(got[signs < 0] & delta)
    assert np.array_equal(got[signs == 0], masks[signs == 0])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(1, 3000), st.integers(0, 2 ** 32 - 1))
def test_bitmap_patch_roundtrip_property(rows, n_words, seed):
    """OR then AND-NOT of the same delta must clear every delta bit."""
    r = np.random.default_rng(seed)
    masks = r.integers(0, 2 ** 32, size=(rows, n_words), dtype=np.uint32)
    delta = r.integers(0, 2 ** 32, size=n_words, dtype=np.uint32)
    ones = np.ones(rows, dtype=np.int32)
    ored = np.asarray(ops.bitmap_patch(masks, delta, ones))
    cleared = np.asarray(ops.bitmap_patch(ored, delta, -ones))
    assert np.array_equal(cleared, masks & ~delta)


@pytest.mark.parametrize("b,h,kv,s,d,dtype", [
    (2, 8, 2, 1000, 64, np.float32),
    (1, 4, 4, 512, 128, np.float32),
    (3, 16, 8, 700, 32, np.float32),
    (2, 8, 8, 256, 64, np.float32),
    (2, 8, 2, 512, 64, jnp.bfloat16),
])
def test_flash_decode_sweep(b, h, kv, s, d, dtype):
    qv = jnp.asarray(RNG.normal(size=(b, h, d)), dtype=dtype)
    kc = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=dtype)
    vc = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=dtype)
    lens = RNG.integers(1, s + 1, size=b)
    lm = (np.arange(s)[None, :] < lens[:, None])
    o1 = ops.flash_decode(qv, kc, vc, lm)
    o2 = ref.flash_decode_ref(qv, kc, vc, jnp.asarray(lm))
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


# ------------------------------------------------- block-clamp regression
def test_align_block_n_unit():
    """The clamp must stay a multiple of 32 (the packed-word kernels assert
    it) while never exceeding the padded row range by more than one word.
    The old ``min(block_n, max(128, n))`` clamp handed 137 straight through."""
    assert ops._align_block_n(1024, 137) == 160      # round UP, not down
    assert ops._align_block_n(1024, 4096) == 1024    # large n: untouched
    assert ops._align_block_n(1024, 128) == 128
    assert ops._align_block_n(100, 5000) == 128      # floor wins, aligned
    assert ops._align_block_n(256, 1) == 128
    for n in (1, 31, 97, 137, 161, 4097):
        for bn in (100, 128, 256, 1024, 4096):
            got = ops._align_block_n(bn, n)
            assert got % 32 == 0 and got >= 32, (bn, n, got)


@pytest.mark.parametrize("n", [97, 137, 261])
def test_adversarial_row_counts_all_kernels(n):
    """Every tunable wrapper at odd row counts with an oversized requested
    block_n: the clamp path must produce aligned blocks and oracle-exact
    results (the regression that motivated ``_align_block_n``)."""
    d, m, k, nq = 32, 4, 7, 3
    Q = RNG.normal(size=(nq, d)).astype(np.float32)
    X = RNG.normal(size=(n, d)).astype(np.float32)
    mask = RNG.random(n) < 0.6
    pad = (-n) % 32
    dense = RNG.random((2, n)) < 0.6
    words = np.stack([
        np.packbits(np.pad(mk, (0, pad)), bitorder="little").view(np.uint32)
        for mk in dense])
    sid = RNG.integers(0, 2, size=nq).astype(np.int32)

    def check(got, want):
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]))

    check(ops.scoped_topk(Q, X, mask, k=k, block_n=4096),
          ref.scoped_topk_ref(jnp.asarray(Q), jnp.asarray(X),
                              jnp.asarray(mask), k=k))
    check(ops.multi_scope_topk(Q, X, words, sid, k=k, block_n=4096),
          ref.multi_scope_topk_ref(jnp.asarray(Q), jnp.asarray(X),
                                   jnp.asarray(words), jnp.asarray(sid),
                                   k=k))
    q_i8, q_s = _quantize(Q)
    x_i8, x_s = _quantize(X)
    sq = _q_norms(x_i8, x_s)
    check(ops.scoped_topk_i8(q_i8, q_s, x_i8, x_s, sq, mask, k=k,
                             block_n=4096),
          ref.scoped_topk_i8_ref(q_i8, q_s, x_i8, x_s, sq, mask, k=k))
    check(ops.multi_scope_topk_i8(q_i8, q_s, x_i8, x_s, sq, words, sid,
                                  k=k, block_n=4096),
          ref.multi_scope_topk_i8_ref(q_i8, q_s, x_i8, x_s, sq, words, sid,
                                      k=k))
    lut = RNG.normal(size=(nq, m, 256)).astype(np.float32)
    codes = RNG.integers(0, 256, size=(n, m)).astype(np.uint8)
    check(ops.scoped_topk_pq(lut, codes, mask, k=k, block_n=4096),
          ref.scoped_topk_pq_ref(lut, codes, mask, k=k))
    check(ops.multi_scope_topk_pq(lut, codes, words, sid, k=k,
                                  block_n=4096),
          ref.multi_scope_topk_pq_ref(lut, codes, words, sid, k=k))
