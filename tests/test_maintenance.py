"""Online index maintenance under streaming churn (ROADMAP item 3).

What streaming churn breaks, and what this file pins down:

* the DSM journal file only ever grew (compact() existed but nothing called
  it) — auto-compaction must bound the file while keeping seqs monotonic
  across compaction + reopen;
* ``VectorStore._deleted_log`` was append-only — consumer cursors must
  bound it under sustained delete load;
* ``PGIndex._connect`` could leave one-way edges when the far side pruned —
  directed-edge symmetry must hold under arbitrary add churn;
* the maintenance ops themselves (PG repair, tombstone compaction + id
  remap, IVF repartition) must be journaled, crash-replayable to the
  bit-identical state, and runnable from the serving scheduler's
  between-batches slots without hurting correctness.
"""
import os

import numpy as np
import pytest

from repro.core import DSM, DSMJournal
from repro.vectordb import (DirectoryVectorDB, MaintenancePolicy, PGIndex,
                            VectorStore)

DIM = 16


# ------------------------------------------------------------------ helpers
def _mkdb(tmp_path, seed=0, n=400, tag="db"):
    """A deterministic db with all four executors built and a warm planner
    cache; two dbs made with the same seed are bit-identical twins."""
    rng = np.random.default_rng(seed)
    db = DirectoryVectorDB(dim=DIM,
                           journal_path=str(tmp_path / f"{tag}.journal"))
    db.mkdir("/a/")
    db.mkdir("/b/")
    db.mkdir("/a/sub/")
    paths = [("/a/", "/b/", "/a/sub/")[i % 3] for i in range(n)]
    ids = db.ingest(rng.normal(size=(n, DIM)).astype(np.float32), paths)
    db.build_ann("flat")
    db.build_ann("sharded")
    db.build_ann("ivf", n_lists=8)
    db.build_ann("pg", max_degree=8, ef_construction=24)
    return db, ids, rng


def _queries(seed=7, b=6):
    return np.random.default_rng(seed).normal(
        size=(b, DIM)).astype(np.float32)


def _flat_results(db, qs):
    out = []
    for q in qs:
        for path in ("/a/", "/b/", "/a/sub/", "/"):
            r = db.dsq(q, path, k=10, executor="flat")
            out.append((r.ids.copy(), r.scores.copy(), r.scope_size))
    return out


def _assert_same_db_state(a, b):
    """Bit-identical twin check across every maintained structure."""
    np.testing.assert_array_equal(a.store.vectors, b.store.vectors)
    assert a.store.n_deleted == b.store.n_deleted
    assert a.store.compact_gen == b.store.compact_gen
    ia, ib = a.executors["ivf"], b.executors["ivf"]
    assert ia.repartition_gen == ib.repartition_gen
    np.testing.assert_array_equal(ia.centers, ib.centers)
    np.testing.assert_array_equal(ia._len, ib._len)
    for la, lb in zip(ia.lists, ib.lists):
        np.testing.assert_array_equal(la, lb)
    pa, pb = a.executors["pg"], b.executors["pg"]
    assert pa.repair_gen == pb.repair_gen
    np.testing.assert_array_equal(pa._n_edges, pb._n_edges)
    np.testing.assert_array_equal(pa.neighbors, pb.neighbors)
    for (ids_a, sc_a, n_a), (ids_b, sc_b, n_b) in zip(
            _flat_results(a, _queries()), _flat_results(b, _queries())):
        np.testing.assert_array_equal(ids_a, ids_b)
        np.testing.assert_array_equal(sc_a, sc_b)
        assert n_a == n_b


# ----------------------------------------------- satellite: journal growth
def test_journal_auto_compacts_under_churn(tmp_path):
    """Regression: DSMJournal.compact() was never called outside tests, so
    a long-lived journal grew without bound. Auto-compaction past the
    resolved-record threshold must bound the file while seqs stay monotonic
    across compactions and reopens."""
    jp = str(tmp_path / "dsm.journal")
    j = DSMJournal(jp, auto_compact_every=16)
    last = -1
    high_water = 0
    for i in range(400):
        seq = j.begin(DSM("mkdir", f"/d{i}/"))
        assert seq > last, "seqs must be strictly monotonic"
        last = seq
        j.commit(seq)
        high_water = max(high_water, os.path.getsize(jp))
    # 400 resolved ops at ~90 bytes/record would be ~70 KiB append-only;
    # auto-compact every 16 must keep the file around one window's worth
    assert os.path.getsize(jp) < 8_000, os.path.getsize(jp)
    assert high_water < 8_000, high_water
    # a crash suspect survives auto-compaction
    crash_seq = j.begin(DSM("move", "/d0/", "/d1/"))
    for i in range(40):
        j.commit(j.begin(DSM("mkdir", f"/e{i}/")))
    reopened = DSMJournal(jp)
    assert reopened.uncommitted() == [
        (crash_seq, DSM("move", "/d0/", "/d1/"))]
    assert reopened.begin(DSM("mkdir", "/x/")) > last


def test_journal_seq_monotonic_across_compact_to_empty(tmp_path):
    """The nasty corner: compaction that leaves ZERO suspects rewrites an
    empty file — without a seq watermark a reopen would restart at 0 and
    recover() could pair an old commit with a new begin."""
    jp = str(tmp_path / "dsm.journal")
    j = DSMJournal(jp)
    seqs = [j.begin(DSM("mkdir", f"/d{i}/")) for i in range(10)]
    for s in seqs:
        j.commit(s)
    j.compact()                           # nothing pending -> watermark only
    reopened = DSMJournal(jp)
    new_seq = reopened.begin(DSM("mkdir", "/z/"))
    assert new_seq > seqs[-1], (new_seq, seqs[-1])
    # crash suspect detection still works post-watermark
    suspects = DSMJournal.recover(jp)
    assert suspects == [DSM("mkdir", "/z/")]


# ------------------------------------------- satellite: deleted-log growth
def test_deleted_log_bounded_by_consumers():
    """Regression: ``_deleted_log`` was append-only. With a registered
    consumer the consumed prefix must be dropped, absolute cursor indexing
    must survive truncation, and a soak of delete waves stays bounded."""
    store = VectorStore(dim=DIM)
    store.add(np.random.default_rng(0).normal(
        size=(4096, DIM)).astype(np.float32))
    h = store.register_log_consumer()
    seen = []
    peak = 0
    for wave in range(64):
        ids = list(range(wave * 64, wave * 64 + 64))
        store.mark_deleted(ids)
        peak = max(peak, len(store.deleted_log))
        got = store.consume_deleted_log(h)
        seen.extend(got)
        assert got == ids, wave
    assert len(store.deleted_log) == 0
    assert peak <= 64, peak               # never more than one wave buffered
    assert seen == list(range(64 * 64))
    # a second consumer starts at the END of the log (no replay of history)
    h2 = store.register_log_consumer()
    fresh = store.add(np.zeros((1, DIM), np.float32))
    store.mark_deleted(fresh)
    assert store.consume_deleted_log(h2) == [int(fresh[0])]
    store.unregister_log_consumer(h)
    store.unregister_log_consumer(h2)


def test_deleted_log_lagging_consumer_keeps_prefix():
    """Truncation only drops what EVERY consumer has seen: a lagging
    consumer pins the log, catching up releases it."""
    store = VectorStore(dim=DIM)
    store.add(np.zeros((256, DIM), np.float32))
    fast = store.register_log_consumer()
    slow = store.register_log_consumer()
    store.mark_deleted(range(100))
    assert store.consume_deleted_log(fast) == list(range(100))
    assert len(store.deleted_log) == 100      # slow still needs them
    assert store.consume_deleted_log(slow) == list(range(100))
    assert len(store.deleted_log) == 0


# -------------------------------------------- satellite: PG edge symmetry
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pg_connect_symmetry_property(seed):
    """Regression: ``_connect`` added a->b then could prune the b->a side
    without dropping a->b, leaving one-way edges. After arbitrary build +
    incremental add churn the directed edge set must be exactly symmetric."""
    rng = np.random.default_rng(seed)
    store = VectorStore(dim=DIM)
    store.add(rng.normal(size=(64, DIM)).astype(np.float32))
    pg = PGIndex(store, max_degree=4, ef_construction=12)
    for _ in range(8):
        new = store.add(rng.normal(
            size=(int(rng.integers(1, 9)), DIM)).astype(np.float32))
        pg.add(new)
        audit = pg.audit()
        assert audit["asymmetric"] == 0, audit


def test_pg_repair_heals_dead_and_reconnects(tmp_path):
    db, ids, rng = _mkdb(tmp_path, n=300)
    pg = db.executors["pg"]
    for i in ids[::3]:
        db.delete(int(i))
    before = pg.audit()
    assert before["dead"] > 0
    out = pg.repair()
    after = pg.audit()
    assert after["dead"] == 0, after
    assert after["asymmetric"] == 0, after
    assert out["dropped_edges"] >= before["dead"]
    assert pg.repair_gen == 1
    # entry point stays alive
    assert db.store.alive_bool()[pg._entry]


# --------------------------------------------------- tentpole: maintenance
def test_compact_propagates_remap_everywhere(tmp_path):
    """One compaction, then every id-bearing structure must agree with a
    from-scratch twin: scope indexes (no epoch bumps), planner mask cache
    (tokens carried), sharded device masks (word-patched), IVF member
    lists, PG adjacency, hot-pin pools."""
    db, ids, rng = _mkdb(tmp_path)
    qs = _queries()
    # warm the planner mask cache + sharded executor before the remap
    db.dsq_batch(qs, ["/a/"] * len(qs), k=10, executor="sharded")
    planner = db.planner()
    cached_before = len(planner.cache._entries)
    assert cached_before > 0
    for i in ids[:150]:
        db.delete(int(i))
    mgr = db.maintenance(policy=MaintenancePolicy(repair_deletes=10 ** 9))
    ran = mgr.run_all()
    kinds = [r["kind"] for r in ran]
    assert "maint_compact" in kinds, kinds
    assert len(db.store) == 250
    assert db.store.n_deleted == 0
    db.check_invariants()
    # cache entries were patched, not evicted
    assert len(planner.cache._entries) == cached_before
    assert planner.cache.patched >= cached_before
    # journal clean: every maintenance op BEGIN has its COMMIT
    assert mgr.stats()["journal_pending"] == 0
    # a twin built directly from the surviving rows answers identically
    alive_rows = db.store.vectors.copy()
    twin = DirectoryVectorDB(dim=DIM,
                             journal_path=str(tmp_path / "twin.journal"))
    twin.mkdir("/a/")
    twin.mkdir("/b/")
    twin.mkdir("/a/sub/")
    paths = [("/a/", "/b/", "/a/sub/")[i % 3] for i in range(400)]
    kept = [p for i, p in enumerate(paths) if i >= 150]
    twin.ingest(alive_rows, kept)
    twin.build_ann("flat")
    for q in qs:
        for path in ("/a/", "/b/", "/a/sub/", "/"):
            got = db.dsq(q, path, k=10, executor="flat")
            want = twin.dsq(q, path, k=10, executor="flat")
            assert got.scope_size == want.scope_size, path
            np.testing.assert_array_equal(got.ids, want.ids)
            np.testing.assert_array_equal(got.scores, want.scores)
    # sharded device masks were patched in place and still agree
    for q in qs:
        got = db.dsq(q, "/a/", k=10, executor="sharded")
        want = db.dsq(q, "/a/", k=10, executor="flat")
        np.testing.assert_array_equal(got.ids, want.ids)


def test_repartition_reclaims_pad_waste(tmp_path):
    """Churn-heavy IVF: deletes + drifted re-ingest bloat the padded CSR;
    repartition must reclaim the waste and keep answers exact-in-scope."""
    db, ids, rng = _mkdb(tmp_path, n=600)
    ivf = db.executors["ivf"]
    for i in ids[:300]:
        db.delete(int(i))
    # drifted refill concentrates mass away from the frozen centroids
    db.ingest(rng.normal(loc=3.0, size=(300, DIM)).astype(np.float32),
              ["/b/"] * 300)
    waste_before = ivf.pad_waste()
    out = ivf.repartition(seed=0, n_iters=4)
    assert ivf.repartition_gen == 1
    assert out["pad_waste_after"] <= waste_before
    # member lists hold exactly the alive rows, each exactly once
    members = np.concatenate(
        [d[: int(ln)] for d, ln in zip(ivf._data, ivf._len)])
    alive = np.nonzero(db.store.alive_bool())[0]
    np.testing.assert_array_equal(np.sort(members), alive)
    db.check_invariants()


def test_churn_soak_bounded_and_recall_parity(tmp_path):
    """The headline soak: rounds of ingest / delete / DSM churn with online
    maintenance. Asserts every growth channel stays bounded — journal
    bytes, tombstone log, store rows, CSR pad waste — and that recall@10
    against brute force matches a fresh-built index at the end."""
    rng = np.random.default_rng(0)
    db = DirectoryVectorDB(dim=DIM,
                           journal_path=str(tmp_path / "soak.journal"))
    db.mkdir("/a/")
    db.mkdir("/b/")
    ids = db.ingest(rng.normal(size=(512, DIM)).astype(np.float32),
                    ["/a/" if i % 2 else "/b/" for i in range(512)])
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=8)
    db.build_ann("pg", max_degree=8, ef_construction=32)
    mgr = db.maintenance(policy=MaintenancePolicy(
        tombstone_min=32, tombstone_fraction=0.10,
        pad_waste_min=64, pad_waste_fraction=0.25, repair_deletes=16))
    alive = [int(i) for i in ids]
    journal_peak = 0
    for rnd in range(12):
        # delete a batch, re-ingest a drifted batch (steady-state churn)
        kill = rng.choice(len(alive), size=48, replace=False)
        for j in sorted(kill, reverse=True):
            db.delete(alive.pop(j))
        loc = float(rng.normal(scale=2.0))
        new = db.ingest(rng.normal(loc=loc,
                                   size=(48, DIM)).astype(np.float32),
                        ["/a/" if i % 2 else "/b/" for i in range(48)])
        alive = [int(i) for i in new] + alive
        db.mkdir(f"/b/r{rnd}/")
        db.move(f"/b/r{rnd}/", "/a/")
        mgr.run_all()
        db.check_invariants()
        journal_peak = max(journal_peak,
                           os.path.getsize(str(tmp_path / "soak.journal.fs")))
        # compaction remaps ids; refresh the alive list from the store
        alive = np.nonzero(db.store.alive_bool())[0].tolist() \
            if db.store.alive_bool() is not None else list(range(len(db.store)))
    stats = mgr.stats()
    assert stats["ops_run"].get("maint_compact", 0) >= 1, stats
    assert stats["ops_run"].get("maint_pg_repair", 0) >= 1, stats
    assert stats["journal_pending"] == 0
    # -- bounded growth channels ----------------------------------------
    assert len(db.store) <= 512 + 3 * 48, len(db.store)   # rows reclaimed
    assert len(db.store.deleted_log) <= 512               # log truncated
    assert journal_peak < 512 * 1024, journal_peak        # file compacted
    ivf = db.executors["ivf"]
    n_alive = int(db.store.alive_count())
    assert ivf.pad_waste() <= max(64, n_alive), ivf.pad_waste()
    # -- recall parity vs a fresh-built index ---------------------------
    qs = rng.normal(size=(24, DIM)).astype(np.float32)
    fresh = DirectoryVectorDB(dim=DIM)
    fresh.mkdir("/a/")
    fresh.ingest(db.store.vectors[db.store.alive_bool()]
                 if db.store.alive_bool() is not None else db.store.vectors,
                 ["/"] * n_alive)
    fresh.build_ann("flat")
    fresh.build_ann("ivf", n_lists=8)
    fresh.build_ann("pg", max_degree=8, ef_construction=32)

    def recall(d, executor, **kw):
        hits = total = 0
        for q in qs:
            exact = d.dsq(q, "/", k=10, executor="flat")
            got = d.dsq(q, "/", k=10, executor=executor, **kw)
            want_ids = {int(i) for i in exact.ids[0] if int(i) >= 0}
            got_ids = {int(i) for i in got.ids[0] if int(i) >= 0}
            hits += len(want_ids & got_ids)
            total += len(want_ids)
        return hits / max(total, 1)

    maintained = recall(db, "pg", ef_search=64)
    baseline = recall(fresh, "pg", ef_search=64)
    assert maintained >= baseline - 0.05, (maintained, baseline)
    # IVF parity vs fresh-built at the same nprobe (absolute recall at low
    # nprobe is workload-dependent under adversarial drift)...
    ivf_m = recall(db, "ivf", nprobe=4)
    ivf_f = recall(fresh, "ivf", nprobe=4)
    assert ivf_m >= ivf_f - 0.05, (ivf_m, ivf_f)
    # ...and probing every list after 12 rounds of remap/repartition must
    # still be EXACT (the correctness floor of the maintained member lists)
    assert recall(db, "ivf", nprobe=8) == 1.0


# ----------------------------------------------------- crash recovery
@pytest.mark.parametrize("kind", ["maint_pg_repair", "maint_compact",
                                  "maint_repartition"])
def test_kill_point_before_apply_recovers_bit_identical(kind, tmp_path):
    """Crash between journal BEGIN and the mutation: recover() must roll
    the op forward to the bit-identical state of a twin that never
    crashed."""
    db_a, ids_a, _ = _mkdb(tmp_path, seed=3, tag="a")
    db_b, ids_b, _ = _mkdb(tmp_path, seed=3, tag="b")
    for i in ids_a[:120]:
        db_a.delete(int(i))
        db_b.delete(int(i))
    mgr_a = db_a.maintenance()
    mgr_b = db_b.maintenance()
    # twin A runs the op normally
    mgr_a._run(kind)
    # twin B journals the intent, then "crashes" before applying
    op = mgr_b._intent(kind)
    db_b._dsm["fs"].journal.begin(op)
    replayed = db_b.recover()
    assert [o.kind for o in replayed["fs"]] == [kind]
    assert mgr_b.ops_replayed == {kind: 1}
    assert mgr_b.stats()["journal_pending"] == 0
    _assert_same_db_state(db_a, db_b)
    db_b.check_invariants()


@pytest.mark.parametrize("kind", ["maint_pg_repair", "maint_compact",
                                  "maint_repartition"])
def test_kill_point_after_apply_skips_reapply(kind, tmp_path):
    """Crash between the mutation and COMMIT: the generation counter has
    advanced past the journaled snapshot, so recover() must only re-commit
    — applying twice would corrupt (a double compact remaps ids twice)."""
    db_a, ids_a, _ = _mkdb(tmp_path, seed=4, tag="a")
    db_b, ids_b, _ = _mkdb(tmp_path, seed=4, tag="b")
    for i in ids_a[:120]:
        db_a.delete(int(i))
        db_b.delete(int(i))
    mgr_a = db_a.maintenance()
    mgr_b = db_b.maintenance()
    mgr_a._run(kind)
    op = mgr_b._intent(kind)              # gen snapshot BEFORE the apply
    db_b._dsm["fs"].journal.begin(op)
    mgr_b._apply(op)                      # mutation lands...
    # ...then crash: no COMMIT. recover() sees the advanced counter.
    replayed = db_b.recover()
    assert replayed["fs"] == [], replayed
    assert mgr_b.ops_replayed == {}
    assert mgr_b.stats()["journal_pending"] == 0
    _assert_same_db_state(db_a, db_b)
    db_b.check_invariants()


def test_recover_without_manager_drops_intent_safely(tmp_path):
    """recover() with no manager wired must NOT guess at a maint_* suspect:
    the intent is dropped (journal resolved, state untouched) and the
    condition that made it due re-triggers it at the next due() check —
    maintenance intents are advisory, unlike structural DSM."""
    db, ids, _ = _mkdb(tmp_path, seed=5)
    for i in ids[:120]:
        db.delete(int(i))
    mgr = db.maintenance()
    op = mgr._intent("maint_compact")
    db._dsm["fs"].journal.begin(op)
    # hook unwired (simulates a restart that forgot db.maintenance())
    db._dsm["fs"].maintenance_replay = None
    replayed = db.recover()
    assert replayed["fs"] == []
    assert len(db._dsm["fs"].journal.uncommitted()) == 0
    assert db.store.n_deleted == 120      # state untouched
    db.check_invariants()
    # the tombstones are still there, so the op is simply due again
    assert "maint_compact" in mgr.due()
    mgr.run_all()
    assert db.store.n_deleted == 0
    db.check_invariants()


# ------------------------------------------------- scheduler integration
def test_scheduler_runs_maintenance_between_batches(tmp_path):
    from repro.serving import ScheduledDSQ
    db, ids, rng = _mkdb(tmp_path, seed=6)
    for i in ids[:150]:
        db.delete(int(i))
    s = ScheduledDSQ(db, k=5, maintenance=True, maintenance_every=2)
    qs = rng.normal(size=(16, DIM)).astype(np.float32)
    futs = [s.submit(qs[i], "/a/") for i in range(16)]
    for _ in range(64):
        if all(f.done() for f in futs):
            break
        s.pump()
    results = [f.result(timeout=5) for f in futs]
    for _ in range(64):
        s.pump()                          # idle pumps force slots; each
    assert s.scheduler.maintenance_steps >= 2     # runs at most ONE op
    assert s.scheduler.maintenance_error is None
    assert db.store.n_deleted == 0        # compaction happened
    db.check_invariants()
    # every ticket was answered (results reference ids as of their batch's
    # epoch; a later compaction does not invalidate served responses)
    assert all(r is not None and len(r.ids[0]) == 5 for r in results)
    # post-maintenance serving agrees with a direct dsq on the new state
    f2 = s.submit(qs[0], "/a/")
    for _ in range(16):
        if f2.done():
            break
        s.pump()
    direct = db.dsq(qs[0], "/a/", k=5, executor="flat")
    np.testing.assert_array_equal(f2.result(timeout=5).ids, direct.ids)


def test_scheduler_maintenance_threaded(tmp_path):
    import time

    from repro.serving import ScheduledDSQ
    db, ids, rng = _mkdb(tmp_path, seed=7)
    for i in ids[:150]:
        db.delete(int(i))
    qs = rng.normal(size=(16, DIM)).astype(np.float32)
    with ScheduledDSQ(db, k=5, maintenance=True, maintenance_every=2) as s:
        futs = [s.submit(qs[i % 16], "/b/") for i in range(32)]
        out = [f.result(timeout=30) for f in futs]
        deadline = time.time() + 5
        while s.scheduler.maintenance_steps == 0 and time.time() < deadline:
            time.sleep(0.01)              # idle loop runs forced slots
    assert all(o is not None for o in out)
    assert s.scheduler.maintenance_steps >= 1
    assert s.scheduler.maintenance_error is None
    db.check_invariants()


def test_scheduler_survives_maintenance_hook_error(tmp_path):
    from repro.serving import ScheduledDSQ

    def boom():
        raise RuntimeError("maintenance exploded")

    db, ids, rng = _mkdb(tmp_path, seed=8, n=64)
    s = ScheduledDSQ(db, k=5, maintenance=boom, maintenance_every=1)
    f = s.submit(rng.normal(size=DIM).astype(np.float32), "/a/")
    for _ in range(16):
        if f.done():
            break
        s.pump()
    assert f.result(timeout=5) is not None
    s.pump()                              # idle slot triggers the hook
    assert s.scheduler.maintenance_error is not None
    # hook disabled, serving continues
    f2 = s.submit(rng.normal(size=DIM).astype(np.float32), "/a/")
    for _ in range(16):
        if f2.done():
            break
        s.pump()
    assert f2.result(timeout=5) is not None


# --------------------------------------------- injected journal write faults
# Chaos-PR satellite: partial journal failures (short write / ENOSPC /
# fsync fault) at every maintenance op kind, in both phases. The twin
# contract follows what reached the disk:
#   BEGIN write lost (short_write/enospc) -> op never ran -> twins diverge
#     only by the op never having happened (db_b equals its own pre-op
#     state, journal has no suspect);
#   BEGIN durable but fsync faulted -> rolled forward on recover();
#   COMMIT write faulted -> mutation ran, gen-counter probe skips reapply.
from repro import faults as F  # noqa: E402


@pytest.mark.parametrize("kind", ["maint_pg_repair", "maint_compact",
                                  "maint_repartition"])
@pytest.mark.parametrize("fault", ["short_write", "enospc", "fsync"])
@pytest.mark.parametrize("phase", ["begin", "commit"])
def test_maintenance_recovery_under_injected_journal_faults(
        kind, fault, phase, tmp_path):
    db_a, ids_a, _ = _mkdb(tmp_path, seed=5, tag="a")
    db_b, ids_b, _ = _mkdb(tmp_path, seed=5, tag="b")
    for i in ids_a[:120]:
        db_a.delete(int(i))
        db_b.delete(int(i))
    db_b._dsm["fs"].journal.fsync_on_commit = True
    mgr_a = db_a.maintenance()
    mgr_b = db_b.maintenance()

    seam = "journal.fsync" if fault == "fsync" else "journal.write"
    fkind = "error" if fault == "fsync" else fault
    plan = F.FaultPlan().add(seam, kind=fkind,
                             after=0 if phase == "begin" else 1)
    with F.FaultInjector(plan):
        with pytest.raises((F.FaultError, F.InjectedCrash, OSError)):
            mgr_b._run(kind)

    begin_lost = (phase == "begin" and fault in ("short_write", "enospc"))
    # restart: reopen the journal from disk (the in-memory intent set died
    # with the "process"; reopen also truncates any torn tail) and recover
    ex_b = db_b._dsm["fs"]
    ex_b.journal = DSMJournal(ex_b.journal.path, fsync_on_commit=True)
    replayed = db_b.recover()
    if begin_lost:
        # intent never durable: the op never happened on db_b; run it now
        # so both twins converge on the same post-op state
        assert replayed["fs"] == []
        assert mgr_b.ops_replayed == {}
        mgr_b._run(kind)
    elif phase == "begin":
        # fsync faulted but the BEGIN record is on disk: rolled forward
        assert [o.kind for o in replayed["fs"]] == [kind]
        assert mgr_b.ops_replayed == {kind: 1}
    else:
        # mutation landed, COMMIT lost: the gen-counter probe must skip
        # reapply (fsync@commit leaves no suspect at all — the record is
        # durable — so replay may be empty either way)
        assert mgr_b.ops_replayed.get(kind, 0) == 0 or fault == "fsync"
    mgr_a._run(kind)
    assert mgr_b.stats()["journal_pending"] == 0
    _assert_same_db_state(db_a, db_b)
    db_b.check_invariants()
