"""Per-architecture smoke tests: reduced same-family config, one forward /
train / prefill / decode step on CPU, asserting output shapes + no NaNs.
(The FULL configs are exercised only via the allocation-free dry-run.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, SHAPES, cell_applicable, get_arch,
                           smoke_config)
from repro.models import decode_step, loss_fn, model_schema, prefill
from repro.models.layers import init_params

RNG = np.random.default_rng(0)


def _batch(cfg, B=2, S=16):
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(B, S)),
                         jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", list(ARCHS))
def test_arch_train_and_serve_smoke(name):
    cfg = smoke_config(name)
    params = init_params(model_schema(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype())
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    loss = loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: loss_fn(p, batch, cfg))(params)
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all(), (name, path)
    # prefill -> decode two tokens
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits, cache = prefill(params, batch, cfg, cache_seq=S + cfg.meta_tokens
                            + 4)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(2):
        logits, cache = decode_step(params, cache, nxt, cfg, extra=extra)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("name", ["qwen3-0.6b", "mamba2-130m"])
def test_prefill_decode_matches_full_forward(name):
    """Greedy decode from a prefix must equal teacher-forced argmax: the
    KV/SSM cache path and the train path are the same function."""
    cfg = smoke_config(name)
    params = init_params(model_schema(cfg), jax.random.PRNGKey(1),
                         cfg.param_dtype())
    B, S = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    from repro.models.transformer import forward, logits_from_hidden
    h, _ = forward(params, toks, cfg)
    full_logits = logits_from_hidden(params, h, cfg)
    logits_p, cache = prefill(params, {"tokens": toks[:, :-1]}, cfg,
                              cache_seq=S + cfg.meta_tokens + 2)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-3, atol=2e-3)
    logits_d, _ = decode_step(params, cache, toks[:, -1:], cfg)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


def test_unrolled_matches_scan():
    cfg = smoke_config("qwen3-0.6b")
    params = init_params(model_schema(cfg), jax.random.PRNGKey(2),
                         cfg.param_dtype())
    batch = _batch(cfg)
    l1 = loss_fn(params, batch, cfg)
    l2 = loss_fn(params, batch, cfg.replace(scan_layers=False))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_naive_attn_matches_flash_loss():
    cfg = smoke_config("granite-8b")
    params = init_params(model_schema(cfg), jax.random.PRNGKey(3),
                         cfg.param_dtype())
    batch = _batch(cfg)
    l1 = loss_fn(params, batch, cfg)
    l2 = loss_fn(params, batch, cfg.replace(attn_impl="naive"))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


def test_loss_chunking_matches():
    cfg = smoke_config("qwen2.5-3b")
    params = init_params(model_schema(cfg), jax.random.PRNGKey(4),
                         cfg.param_dtype())
    batch = _batch(cfg, B=2, S=16)
    l1 = loss_fn(params, batch, cfg)
    l2 = loss_fn(params, batch, cfg.replace(loss_chunk=4))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_cell_applicability_rules():
    skipped = [(a, s) for a in ARCHS for s in SHAPES
               if not cell_applicable(get_arch(a), SHAPES[s])[0]]
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "granite-8b", "qwen2.5-3b", "qwen3-0.6b", "minitron-4b",
        "phi-3-vision-4.2b", "deepseek-moe-16b", "whisper-large-v3"}
    for name in ("hymba-1.5b", "mamba2-130m", "llama4-scout-17b-a16e"):
        assert cell_applicable(get_arch(name), SHAPES["long_500k"])[0]


def test_param_counts_match_model_sizes():
    """Full configs land near their nameplate sizes (sanity on fidelity)."""
    expect = {"granite-8b": 8.25e9, "qwen2.5-3b": 3.4e9, "qwen3-0.6b": 0.6e9,
              "minitron-4b": 4.19e9, "mamba2-130m": 0.13e9,
              "llama4-scout-17b-a16e": 108e9, "deepseek-moe-16b": 16.9e9,
              "hymba-1.5b": 1.65e9, "whisper-large-v3": 1.6e9,
              "phi-3-vision-4.2b": 3.8e9}
    for name, want in expect.items():
        got = get_arch(name).param_count()
        assert abs(got - want) / want < 0.05, (name, got, want)
    # MoE active params: llama4 top-1 of 16 + shared ~ 17B active
    active = get_arch("llama4-scout-17b-a16e").active_param_count()
    assert 14e9 < active < 20e9, active
