import pytest

from repro.core import paths as P


def test_parse_and_render():
    assert P.parse("/a/b/") == ("a", "b")
    assert P.parse("/a/b") == ("a", "b")
    assert P.parse("a/b/") == ("a", "b")
    assert P.parse("/") == ()
    assert P.parse(("x",)) == ("x",)
    assert P.to_str(("a", "b")) == "/a/b/"
    assert P.to_str(()) == "/"


def test_parse_rejects_relative():
    with pytest.raises(ValueError):
        P.parse("/a/../b/")


def test_ancestors_and_relations():
    p = P.parse("/a/b/c/")
    assert list(P.ancestors(p)) == [(), ("a",), ("a", "b"), ("a", "b", "c")]
    assert list(P.ancestors(p, include_self=False))[-1] == ("a", "b")
    assert P.is_ancestor((), p)
    assert P.is_ancestor(("a",), p, proper=True)
    assert not P.is_ancestor(p, p, proper=True)
    assert P.is_ancestor(p, p)
    assert not P.is_ancestor(("a", "x"), p)


def test_prefix_ops():
    assert P.replace_prefix(("a", "b", "c"), ("a",), ("z", "y")) == \
        ("z", "y", "b", "c")
    with pytest.raises(ValueError):
        P.replace_prefix(("a", "b"), ("x",), ("z",))
    assert P.common_prefix(("a", "b", "c"), ("a", "b", "z")) == ("a", "b")
    assert P.common_prefix(("a",), ("b",)) == ()
    assert P.relative(("a", "b", "c"), ("a",)) == ("b", "c")


def test_validate_disjoint():
    P.validate_disjoint(("a",), ("b",))
    with pytest.raises(ValueError):
        P.validate_disjoint(("a",), ("a", "b"))
    with pytest.raises(ValueError):
        P.validate_disjoint(("a", "b"), ("a",))
