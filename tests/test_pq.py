"""PQ/ADC device tier + scope-aware tiered fp32 storage.

Contracts under test:

* codebook mechanics — subspace k-means training, frozen-codebook
  incremental encoding, the metric-folding LUT identity (ADC score ==
  decoded-approximation score for ip/l2/cos);
* PQ Pallas kernels == numpy oracles across block shapes, empty scopes and
  all-masked tiles;
* two-phase executor contract — the PQ phase only *selects* candidates, the
  exact fp32 gather-rescore ranks, so exhaustive ``rescore_k`` reproduces
  the fp32 top-k set on flat/sharded;
* planner precision selection, alive-row byte accounting (tombstones
  excluded), tiered-storage placement/fetch accounting, and the fp32→pq
  auto-upgrade when the store exceeds its device byte budget.
"""
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.ref import multi_scope_topk_pq_ref, scoped_topk_pq_ref
from repro.vectordb import DirectoryVectorDB
from repro.vectordb.flat import FlatExecutor
from repro.vectordb.planner import BatchAccounting
from repro.vectordb.quant import PQCodebook, default_pq_m
from repro.vectordb.sharded import ShardedExecutor
from repro.vectordb.store import VectorStore, pack_ids_to_words

RNG = np.random.default_rng(0)
DIM = 32


# ---------------------------------------------------------------- codebook
def test_default_pq_m():
    assert default_pq_m(16) == 4
    assert default_pq_m(24) == 6
    assert default_pq_m(32) == 8
    assert default_pq_m(64) == 16
    assert 64 % default_pq_m(64) == 0


def test_codebook_requires_divisible_m():
    with pytest.raises(ValueError):
        PQCodebook(dim=32, m=5)


def test_codebook_roundtrip_and_compression():
    rows = RNG.normal(size=(800, DIM)).astype(np.float32)
    cb = PQCodebook(DIM)
    cb.train(rows)
    codes = cb.encode(rows)
    assert codes.dtype == np.uint8 and codes.shape == (800, cb.m)
    back = cb.decode(codes)
    # decoded approximation is closer to the row than a random other row
    err = np.linalg.norm(back - rows, axis=1).mean()
    base = np.linalg.norm(rows[RNG.permutation(800)] - rows, axis=1).mean()
    assert err < 0.5 * base
    assert codes.nbytes == 800 * cb.m == rows.nbytes // (4 * DIM // cb.m)


@pytest.mark.parametrize("metric", ["ip", "l2", "cos"])
def test_lut_adc_identity(metric):
    """sum_m lut[m, code_m] must equal the executor's scoring expression
    evaluated on the decoded approximation (the ADC correctness identity;
    for l2 that is the larger-is-better ``2 q.x - ||x||^2`` form)."""
    rows = RNG.normal(size=(300, DIM)).astype(np.float32)
    q = RNG.normal(size=(5, DIM)).astype(np.float32)
    cb = PQCodebook(DIM)
    cb.train(rows)
    codes = cb.encode(rows)
    back = cb.decode(codes)
    lut = cb.lut(q, metric)
    assert lut.shape == (5, cb.m, 256)
    adc = lut[:, np.arange(cb.m)[None, :], codes.astype(np.int64)].sum(axis=2)
    if metric == "l2":
        want = 2.0 * q @ back.T - np.einsum("nd,nd->n", back, back)[None, :]
    else:
        want = q @ back.T
    np.testing.assert_allclose(adc, want, rtol=1e-4, atol=1e-4)


def test_store_incremental_pq_maintenance():
    """Codes always mirror encode(all rows) under the frozen codebook,
    through incremental adds and capacity growth; the codebook trains once
    and never re-trains (stable codes for already-encoded rows)."""
    st = VectorStore(DIM, "ip", capacity=4)
    st.add(RNG.normal(size=(70, DIM)).astype(np.float32))
    first = st.pq_codes.copy()
    cb = st.pq_codebook
    st.add(RNG.normal(size=(50, DIM)).astype(np.float32))
    assert st.pq_codebook is cb                       # frozen, not retrained
    np.testing.assert_array_equal(st.pq_codes[:70], first)
    np.testing.assert_array_equal(st.pq_codes, cb.encode(st.vectors))
    assert st.pq_nbytes() == len(st) * cb.m
    assert st.pq_nbytes() <= 0.08 * st.alive_nbytes()


def test_store_alive_byte_accounting_excludes_tombstones():
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(100, DIM)).astype(np.float32))
    assert st.alive_nbytes() == st.nbytes()
    assert st.q_alive_nbytes() == st.q_nbytes()
    m = st.pq_codebook.m
    st.mark_deleted(np.arange(10))
    assert st.alive_nbytes() == 90 * DIM * 4
    assert st.q_alive_nbytes() == 90 * (DIM + 4)
    assert st.pq_nbytes() == 90 * m
    assert st.nbytes() == 100 * DIM * 4      # buffer bytes: unchanged


def test_sharded_view_pq_mirror_incremental():
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(40, DIM)).astype(np.float32))
    ex = ShardedExecutor(st)
    ex.sync()
    pq = ex.view.pq_device()
    assert pq.dtype == np.uint8 and pq.shape == (ex.view.cap, st.pq_codebook.m)
    np.testing.assert_array_equal(np.asarray(pq)[:40], st.pq_codes)
    up0 = ex.view.pq_bytes_uploaded
    if ex.view.cap - len(st) > 2:
        st.add(RNG.normal(size=(2, DIM)).astype(np.float32))
        ex.sync()
        pq = ex.view.pq_device()
        np.testing.assert_array_equal(np.asarray(pq)[:42], st.pq_codes)
        assert 0 < ex.view.pq_bytes_uploaded - up0 < up0
    st.add(RNG.normal(size=(ex.view.cap, DIM)).astype(np.float32))
    ex.sync()
    pq = ex.view.pq_device()
    assert pq.shape[0] == ex.view.cap
    np.testing.assert_array_equal(np.asarray(pq)[: len(st)], st.pq_codes)


# ----------------------------------------------------------------- kernels
def _pq_fixture(nq, n, m, seed):
    rng = np.random.default_rng(seed)
    lut = rng.normal(size=(nq, m, 256)).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.uint8)
    return lut, codes


@pytest.mark.parametrize("nq,n,m,k", [(5, 300, 4, 10), (1, 33, 8, 5),
                                      (8, 2050, 16, 7), (3, 64, 6, 10)])
def test_scoped_topk_pq_kernel_matches_oracle(nq, n, m, k):
    lut, codes = _pq_fixture(nq, n, m, seed=nq * n)
    mask = (np.random.default_rng(n).random(n) < 0.7)
    want_v, want_i = scoped_topk_pq_ref(lut, codes, mask, k=k)
    got_v, got_i = kops.scoped_topk_pq(lut, codes, mask, k=k)
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_i), want_i)


@pytest.mark.parametrize("nq,n,m,k", [(6, 500, 8, 10), (2, 96, 4, 5)])
def test_multi_scope_topk_pq_kernel_matches_oracle(nq, n, m, k):
    rng = np.random.default_rng(7)
    lut, codes = _pq_fixture(nq, n, m, seed=99)
    n_scopes = 3
    words = np.stack([pack_ids_to_words(
        np.flatnonzero(rng.random(n) < 0.6).astype(np.uint32), n)
        for _ in range(n_scopes)])
    sids = rng.integers(0, n_scopes, size=nq).astype(np.int32)
    want_v, want_i = multi_scope_topk_pq_ref(lut, codes, words, sids, k=k)
    got_v, got_i = kops.multi_scope_topk_pq(lut, codes, words, sids, k=k)
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_i), want_i)


def test_pq_kernel_empty_scope_and_all_masked():
    lut, codes = _pq_fixture(3, 256, 8, seed=1)
    mask = np.zeros(256, dtype=bool)
    v, i = kops.scoped_topk_pq(lut, codes, mask, k=5)
    assert (np.asarray(i) == -1).all()
    assert (np.asarray(v) <= np.finfo(np.float32).min).all()
    words = np.zeros((2, 256 // 32), dtype=np.uint32)
    sids = np.zeros(3, dtype=np.int32)
    v, i = kops.multi_scope_topk_pq(lut, codes, words, sids, k=5)
    assert (np.asarray(i) == -1).all()


def test_pq_kernel_scope_narrower_than_k():
    lut, codes = _pq_fixture(2, 128, 4, seed=2)
    mask = np.zeros(128, dtype=bool)
    mask[[5, 60]] = True
    v, i = kops.scoped_topk_pq(lut, codes, mask, k=10)
    got = np.asarray(i)
    assert set(got[got >= 0].tolist()) <= {5, 60}
    assert (got[:, 2:] == -1).all()


# --------------------------------------------------------------- executors
@pytest.mark.parametrize("metric", ["ip", "l2", "cos"])
def test_flat_pq_exhaustive_rescore_equals_fp32(metric):
    st = VectorStore(DIM, metric)
    st.add(RNG.normal(size=(1500, DIM)).astype(np.float32))
    ex = FlatExecutor(st)
    q = RNG.normal(size=(4, DIM)).astype(np.float32)
    sf, i_f = ex.search(q, 10)
    sp, ip_ = ex.search(q, 10, precision="pq", rescore_k=1500)
    np.testing.assert_array_equal(i_f, ip_)
    np.testing.assert_allclose(sf, sp, rtol=1e-4, atol=1e-4)


def test_flat_pq_gather_plans():
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(4000, DIM)).astype(np.float32))
    ex = FlatExecutor(st)
    q = RNG.normal(size=(3, DIM)).astype(np.float32)
    small = np.arange(30, dtype=np.uint32)          # 30 <= rescore_k=40
    sf, i_f = ex.search(q, 10, candidate_ids=small)
    sp, ip_ = ex.search(q, 10, candidate_ids=small, precision="pq")
    np.testing.assert_array_equal(i_f, ip_)
    np.testing.assert_array_equal(sf, sp)           # identical fp32 launch
    big = np.arange(150, dtype=np.uint32)           # gather plan, > window
    spb, ipb = ex.search(q, 10, candidate_ids=big, precision="pq")
    assert set(ipb.ravel().tolist()) <= set(range(150))
    assert np.isfinite(spb).all()
    s, i = ex.search(q, 5, candidate_ids=np.empty(0, np.uint32),
                     precision="pq")
    assert (i == -1).all() and not np.isfinite(s).any()


def test_sharded_pq_exhaustive_rescore_equals_fp32():
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(3000, DIM)).astype(np.float32))
    ex = ShardedExecutor(st)
    q = RNG.normal(size=(4, DIM)).astype(np.float32)
    scope = np.arange(0, 3000, 2, dtype=np.uint32)
    sf, i_f = ex.search(q, 10, candidate_ids=scope, plan="scan")
    sp, ip_ = ex.search(q, 10, candidate_ids=scope, plan="scan",
                        precision="pq", rescore_k=3000)
    np.testing.assert_array_equal(i_f, ip_)
    np.testing.assert_allclose(sf, sp, rtol=1e-4, atol=1e-4)


def test_tombstones_respected_by_pq_scan():
    db = DirectoryVectorDB(dim=DIM)
    db.ingest(RNG.normal(size=(600, DIM)).astype(np.float32), ["/x/"] * 600)
    db.build_ann("flat")
    q = RNG.normal(size=DIM).astype(np.float32)
    top = db.dsq(q, "/x/", k=5, precision="pq").ids[0]
    for eid in top[:2]:
        db.delete(int(eid))
    after = db.dsq(q, "/x/", k=5, precision="pq").ids[0]
    assert not (set(after.tolist()) & set(int(x) for x in top[:2]))


# ----------------------------------------------------- planner + accounting
def test_planner_precision_pq_per_group():
    # calibration=False: asserts hand-set planner internals (plan labels
    # depend on the hand-set gather threshold)
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi",
                           calibration=False)
    paths = ["/broad/"] * 900 + ["/narrow/"] * 20
    db.ingest(RNG.normal(size=(920, DIM)).astype(np.float32), paths)
    db.build_ann("flat")
    from repro.core.interface import normalize_batch
    acct = BatchAccounting()
    groups = db.planner().plan(db.namespaces["fs"], len(db.store),
                               normalize_batch(["/broad/", "/narrow/"], True,
                                               None),
                               k=10, acct=acct, precision="pq")
    by_path = {str(g.key.path): g for g in groups}
    broad = by_path[[p for p in by_path if "broad" in p][0]]
    narrow = by_path[[p for p in by_path if "narrow" in p][0]]
    assert broad.plan == "scan" and broad.precision == "pq"
    assert narrow.plan == "gather" and narrow.precision == "fp32"
    assert acct.precision_groups == {"pq": 1, "fp32": 1}


def test_batch_accounting_pq_terms_exclude_tombstones():
    # calibration=False: rescore_candidates == 6 * 40 assumes the hand-set
    # rescore factor
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi",
                           calibration=False)
    ids = db.ingest(RNG.normal(size=(1200, DIM)).astype(np.float32),
                    ["/a/"] * 600 + ["/b/"] * 600)
    db.build_ann("flat")
    m = db.store.pq_codebook.m
    q = RNG.normal(size=(6, DIM)).astype(np.float32)
    res = db.dsq_batch(q, ["/a/", "/b/", "/", "/a/", "/b/", "/"], k=10,
                       precision="pq")
    acct = res[0].batch
    assert acct.db_bytes_fp32 == 1200 * DIM * 4
    assert acct.db_bytes_pq == 1200 * m
    assert acct.db_bytes_pq <= 0.08 * acct.db_bytes_fp32
    assert acct.rescore_candidates == 6 * 40
    assert acct.precision_groups.get("pq") == 3
    for eid in ids[:30]:
        db.delete(int(eid))
    acct2 = db.dsq_batch(q, ["/a/"] * 6, k=10, precision="pq")[0].batch
    assert acct2.db_bytes_fp32 == 1170 * DIM * 4      # tombstones excluded
    assert acct2.db_bytes_pq == 1170 * m
    # default-precision batches carry no pq terms
    acct3 = db.dsq_batch(q, ["/a/"] * 6, k=10)[0].batch
    assert acct3.db_bytes_pq == 0 and acct3.rescore_fetch_bytes == 0
    assert "pq" not in acct3.precision_groups


def test_dsq_still_rejects_unknown_precision():
    db = DirectoryVectorDB(dim=DIM)
    db.ingest(RNG.normal(size=(10, DIM)).astype(np.float32), ["/a/"] * 10)
    db.build_ann("flat")
    q = RNG.normal(size=DIM).astype(np.float32)
    with pytest.raises(ValueError, match="precision"):
        db.dsq(q, "/a/", precision="int4")
    with pytest.raises(ValueError, match="precision"):
        db.dsq_batch(q[None, :], ["/a/"], precision="fp16")


# ----------------------------------------------------------- tiered storage
def _tiered_db(n=2000, n_dirs=8):
    db = DirectoryVectorDB(dim=DIM, metric="ip")
    db.build_ann("flat")
    X = RNG.normal(size=(n, DIM)).astype(np.float32)
    db.ingest(X, [f"/d/{i % n_dirs}/" for i in range(n)])
    return db


def test_tiered_auto_upgrades_fp32_to_pq():
    db = _tiered_db()
    q = RNG.normal(size=(8, DIM)).astype(np.float32)
    paths = [f"/d/{i % 8}/" for i in range(8)]
    base = db.dsq_batch(q, paths, k=10)
    assert "pq" not in base[0].batch.precision_groups   # under budget: fp32
    assert base[0].batch.rows_host == 0
    db.store.set_device_budget(db.store.nbytes() // 4)
    assert db.store.tiered_active()
    res = db.dsq_batch(q, paths, k=10)                  # default precision
    acct = res[0].batch
    assert acct.precision_groups.get("pq", 0) > 0
    assert acct.rescore_fetch_bytes > 0
    assert acct.rows_device_pinned + acct.rows_host == 2000
    # approximate phase + exact rescore: high overlap with the fp32 answer
    rec = np.mean([len(set(a.ids[0]) & set(b.ids[0])) / 10
                   for a, b in zip(base, res)])
    assert rec >= 0.9


def test_tiered_hot_pinning_reduces_fetch():
    db = _tiered_db()
    q = RNG.normal(size=(8, DIM)).astype(np.float32)
    paths = [f"/d/{i % 8}/" for i in range(8)]
    db.store.set_device_budget(db.store.nbytes() // 3)
    a1 = db.dsq_batch(q, paths, k=10)[0].batch
    a2 = db.dsq_batch(q, paths, k=10)[0].batch
    assert a2.rows_device_pinned > 0                 # hot scopes pinned
    assert a2.rescore_fetch_bytes < a1.rescore_fetch_bytes


def test_store_pin_mask_survives_growth():
    """Regression: pin_rows sized the pinned mask to the buffer capacity at
    pin time, so ingest growth left a stale short mask — placement() raised
    a broadcast ValueError and rescore indexing raised IndexError."""
    store = VectorStore(dim=8, capacity=16)
    store.add(RNG.normal(size=(16, 8)).astype(np.float32))
    store.set_device_budget(1)
    store.pin_rows(np.arange(4))
    store.add(RNG.normal(size=(40, 8)).astype(np.float32))   # grows buffer
    dev, host = store.placement()                    # was: ValueError
    assert (dev, host) == (4, 52)
    pm = store.pinned_mask()
    assert pm.shape == (56,)
    assert bool(pm[55]) is False                     # was: IndexError
    assert pm[:4].all() and not pm[4:].any()


def test_tiered_survives_ingest_after_pin():
    """Pins taken before an ingest must not crash the next tiered batch —
    the DSM-era serving loop interleaves ingest with tiered DSQ."""
    db = _tiered_db(n=1500, n_dirs=4)
    db.store.set_device_budget(db.store.nbytes() // 3)
    q = RNG.normal(size=(4, DIM)).astype(np.float32)
    paths = [f"/d/{i % 4}/" for i in range(4)]
    db.dsq_batch(q, paths, k=5)                      # takes pins at n=1500
    assert db.store.pinned_mask().any()
    db.ingest(RNG.normal(size=(1200, DIM)).astype(np.float32),
              [f"/d/{i % 4}/" for i in range(1200)])  # grows past capacity
    pm = db.store.pinned_mask()
    assert pm.shape == (2700,) and not pm[1500:].any()   # new rows unpinned
    dev, host = db.store.placement()                 # no broadcast ValueError
    assert dev + host == 2700
    res = db.dsq_batch(q, paths, k=5)                # no IndexError in rescore
    acct = res[0].batch
    assert acct.rows_device_pinned + acct.rows_host == 2700


def test_tiered_cold_batch_keeps_hot_pins():
    """A batch over cold scopes must not unpin rows hotter scopes claimed in
    earlier batches (the cumulative-heat pin contract)."""
    db = _tiered_db(n=2000, n_dirs=8)
    db.store.set_device_budget(db.store.nbytes() // 3)
    q = RNG.normal(size=(8, DIM)).astype(np.float32)
    hot = ["/d/0/"] * 8
    for _ in range(3):                               # make /d/0/ clearly hot
        db.dsq_batch(q, hot, k=5)
    hot_pins = db.store.pinned_mask().copy()
    hot_ids = set(db.namespaces["fs"].resolve("/d/0/").to_array())
    assert hot_ids & set(np.flatnonzero(hot_pins))
    db.dsq_batch(q[:1], ["/d/7/"], k=5)              # one cold request
    still = set(np.flatnonzero(db.store.pinned_mask()))
    assert set(np.flatnonzero(hot_pins)) & hot_ids <= still


def test_pq_on_empty_store_raises_clear_error():
    store = VectorStore(dim=DIM)
    with pytest.raises(ValueError, match="not trained"):
        store.pq_lut(RNG.normal(size=(1, DIM)).astype(np.float32))
    cb = PQCodebook(DIM)
    with pytest.raises(ValueError, match="not trained"):
        cb.encode(RNG.normal(size=(2, DIM)).astype(np.float32))
    with pytest.raises(ValueError, match="not trained"):
        cb.decode(np.zeros((2, cb.m), np.uint8))


def test_tiered_results_match_explicit_pq():
    """The auto-upgraded plan is exactly the explicit precision="pq" plan."""
    db = _tiered_db()
    q = RNG.normal(size=(6, DIM)).astype(np.float32)
    paths = [f"/d/{i % 3}/" for i in range(6)]
    want = [r.ids.copy() for r in db.dsq_batch(q, paths, k=10,
                                               precision="pq")]
    db.store.set_device_budget(1)
    got = db.dsq_batch(q, paths, k=10)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g.ids)


def test_serving_surfaces_pq_and_tiered_stats():
    from repro.serving.rag import ContextDatabase, RAGConfig
    ctx = ContextDatabase(dim=DIM)
    for i in range(300):
        ctx.add_context(RNG.normal(size=DIM).astype(np.float32),
                        f"/docs/{i % 3}/", "L0", np.arange(4) + i)
    ctx.build("flat")
    cfg = RAGConfig(k=5, precision="pq")
    hits, stats = ctx.retrieve(RNG.normal(size=DIM).astype(np.float32),
                               "/docs/", cfg)
    assert len(hits) == 5
    assert stats["db_bytes_pq"] <= 0.08 * stats["db_bytes_fp32"]
    assert stats["rescore_candidates"] >= 20
    ctx.db.store.set_device_budget(ctx.db.store.nbytes() // 4)
    hits, stats = ctx.retrieve(RNG.normal(size=DIM).astype(np.float32),
                               "/docs/", RAGConfig(k=5))
    assert len(hits) == 5
    assert stats["rows_host"] > 0
    assert "rescore_fetch_bytes" in stats


def test_serving_tiered_stats_survive_full_pin_coverage():
    """Tiered stats are gated on tiered state, not on rows_host being
    nonzero — when the pin budget covers every alive row (rows_host == 0)
    the placement/fetch stats must still surface."""
    from repro.serving.rag import ContextDatabase, RAGConfig
    ctx = ContextDatabase(dim=DIM)
    eids = [ctx.add_context(RNG.normal(size=DIM).astype(np.float32),
                            f"/docs/{i % 3}/", "L0", np.arange(4) + i)
            for i in range(300)]
    ctx.build("flat")
    for eid in eids[20:]:          # tombstones: 20 alive rows, 300 buffered
        ctx.db.delete(eid)
    ctx.db.store.set_device_budget(ctx.db.store.nbytes() - 1)
    hits, stats = ctx.retrieve(RNG.normal(size=DIM).astype(np.float32),
                               "/docs/", RAGConfig(k=5))
    assert len(hits) == 5
    assert stats["rows_host"] == 0                   # everything fit pinned
    assert stats["rows_device_pinned"] == 20
    assert "rescore_fetch_bytes" in stats


# -------------------------------------------------------------- datasets
def test_dirgen_anchor_zipf_skews_scope_access():
    from repro.datasets.dirgen import make_wiki_dir
    flat = make_wiki_dir(scale=0.001, n_queries=200, seed=3)
    skew = make_wiki_dir(scale=0.001, n_queries=200, seed=3, anchor_zipf=1.5)
    # identical corpus (the knob only reshapes query traffic)
    np.testing.assert_array_equal(flat.vectors, skew.vectors)
    assert flat.entry_paths == skew.entry_paths

    def top_share(ds):
        from collections import Counter
        c = Counter(ds.query_anchors)
        return c.most_common(1)[0][1] / len(ds.query_anchors)

    assert top_share(skew) > top_share(flat)
