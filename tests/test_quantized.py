"""int8 scalar-quantized tier: store maintenance, two-phase executor
contracts, planner precision selection, and accounting.

The correctness contract under test everywhere: phase 1 (int8 scan/gather)
only *selects* candidates, phase 2 rescore is exact fp32 — so with
``rescore_k`` covering the candidate universe the int8 path must reproduce
the fp32 exact top-k set, and returned scores are always true fp32 scores.
"""
import numpy as np
import pytest

from repro.vectordb import DirectoryVectorDB
from repro.vectordb.flat import FlatExecutor, gather_rescore
from repro.vectordb.planner import BatchAccounting, BatchPlanner
from repro.vectordb.quant import (DEFAULT_RESCORE_FACTOR, dequantize_rows,
                                  quantize_rows, resolve_rescore_k)
from repro.vectordb.sharded import ShardedExecutor
from repro.vectordb.store import VectorStore

RNG = np.random.default_rng(0)
DIM = 32


# ------------------------------------------------------------------- quant
def test_quantize_roundtrip_error_bound():
    rows = RNG.normal(size=(64, DIM)).astype(np.float32)
    codes, scales = quantize_rows(rows)
    assert codes.dtype == np.int8 and scales.dtype == np.float32
    back = dequantize_rows(codes, scales)
    # symmetric per-row scale: error is at most half a quantization step
    step = np.abs(rows).max(axis=1) / 127.0
    assert np.all(np.abs(back - rows) <= step[:, None] * 0.5 + 1e-7)


def test_quantize_zero_row_total():
    codes, scales = quantize_rows(np.zeros((2, DIM), np.float32))
    assert (codes == 0).all() and (scales == 1.0).all()
    assert np.isfinite(dequantize_rows(codes, scales)).all()


def test_resolve_rescore_k():
    assert resolve_rescore_k(10, None, 10_000) == DEFAULT_RESCORE_FACTOR * 10
    assert resolve_rescore_k(10, 25, 10_000) == 25
    assert resolve_rescore_k(10, 3, 10_000) == 10      # never below k
    assert resolve_rescore_k(10, None, 7) == 7         # never above n


# ------------------------------------------------------------------- store
def test_store_incremental_quantized_maintenance():
    """The int8 codes/scales must always mirror quantize_rows(all rows),
    through multiple incremental adds and capacity growth."""
    st = VectorStore(DIM, "ip", capacity=4)
    chunks = [RNG.normal(size=(n, DIM)).astype(np.float32)
              for n in (3, 17, 50)]
    for c in chunks:
        st.add(c)
    want_codes, want_scales = quantize_rows(np.concatenate(chunks))
    np.testing.assert_array_equal(st.q_vectors, want_codes)
    np.testing.assert_allclose(st.q_scales, want_scales)
    assert st.q_nbytes() == len(st) * (DIM + 4)
    assert st.q_nbytes() < 0.30 * st.nbytes()


def test_store_cos_normalizes_before_quantizing():
    st = VectorStore(DIM, "cos")
    st.add(10.0 * RNG.normal(size=(8, DIM)).astype(np.float32))
    back = dequantize_rows(st.q_vectors, st.q_scales)
    np.testing.assert_allclose(back, st.vectors, atol=0.02)


def test_sharded_view_q_mirror_incremental():
    """The sharded int8 mirror follows ingest growth incrementally and
    rebuilds on a capacity re-shard."""
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(40, DIM)).astype(np.float32))
    ex = ShardedExecutor(st)
    ex.sync()
    qdb, qs = ex.view.q_device()
    assert qdb.dtype == np.int8 and qdb.shape[0] == ex.view.cap
    np.testing.assert_array_equal(np.asarray(qdb)[:40], st.q_vectors)
    up0 = ex.view.q_bytes_uploaded
    # in-capacity growth: incremental scatter, no full re-upload
    if ex.view.cap - len(st) > 2:
        st.add(RNG.normal(size=(2, DIM)).astype(np.float32))
        ex.sync()
        qdb, qs = ex.view.q_device()
        np.testing.assert_array_equal(np.asarray(qdb)[:42], st.q_vectors)
        assert 0 < ex.view.q_bytes_uploaded - up0 < up0
    # growth past capacity: the mirror rebuilds at the doubled capacity
    st.add(RNG.normal(size=(ex.view.cap, DIM)).astype(np.float32))
    ex.sync()
    qdb, qs = ex.view.q_device()
    assert qdb.shape[0] == ex.view.cap
    np.testing.assert_array_equal(np.asarray(qdb)[: len(st)], st.q_vectors)
    np.testing.assert_allclose(np.asarray(qs)[: len(st)], st.q_scales)


# --------------------------------------------------------------- executors
@pytest.mark.parametrize("metric", ["ip", "l2", "cos"])
def test_flat_int8_exhaustive_rescore_equals_fp32(metric):
    st = VectorStore(DIM, metric)
    st.add(RNG.normal(size=(1500, DIM)).astype(np.float32))
    ex = FlatExecutor(st)
    q = RNG.normal(size=(4, DIM)).astype(np.float32)
    sf, i_f = ex.search(q, 10)
    s8, i8 = ex.search(q, 10, precision="int8", rescore_k=1500)
    np.testing.assert_array_equal(i_f, i8)
    np.testing.assert_allclose(sf, s8, rtol=1e-4, atol=1e-4)


def test_flat_int8_gather_plans():
    """Gather-plan int8: scopes inside the rescore window take the exact
    fp32 gather; larger ones prune with int8 first but never leave scope."""
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(4000, DIM)).astype(np.float32))
    ex = FlatExecutor(st)
    q = RNG.normal(size=(3, DIM)).astype(np.float32)
    small = np.arange(30, dtype=np.uint32)          # 30 <= rescore_k=40
    sf, i_f = ex.search(q, 10, candidate_ids=small)
    s8, i8 = ex.search(q, 10, candidate_ids=small, precision="int8")
    np.testing.assert_array_equal(i_f, i8)
    np.testing.assert_array_equal(sf, s8)           # identical fp32 launch
    big = np.arange(150, dtype=np.uint32)           # gather plan, > window
    s8b, i8b = ex.search(q, 10, candidate_ids=big, precision="int8")
    assert set(i8b.ravel().tolist()) <= set(range(150))
    assert np.isfinite(s8b).all()


def test_empty_scope_int8():
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(100, DIM)).astype(np.float32))
    ex = FlatExecutor(st)
    q = RNG.normal(size=(2, DIM)).astype(np.float32)
    s, i = ex.search(q, 5, candidate_ids=np.empty(0, np.uint32),
                     precision="int8")
    assert (i == -1).all() and not np.isfinite(s).any()


def test_gather_rescore_padding_contract():
    """-1 candidates never surface; short candidate lists right-pad."""
    st = VectorStore(DIM, "ip")
    st.add(RNG.normal(size=(50, DIM)).astype(np.float32))
    q = RNG.normal(size=(2, DIM)).astype(np.float32)
    cand = np.array([[3, 7, -1, -1], [-1, -1, -1, -1]], np.int64)
    s, i = gather_rescore(st, q, cand, k=3)
    assert i.shape == (2, 3)
    assert set(i[0].tolist()) <= {3, 7, -1}
    assert (i[1] == -1).all()
    assert int((i[0] >= 0).sum()) == 2


def test_tombstones_respected_by_int8_scan():
    db = DirectoryVectorDB(dim=DIM)
    ids = db.ingest(RNG.normal(size=(600, DIM)).astype(np.float32),
                    ["/x/"] * 600)
    db.build_ann("flat")
    q = RNG.normal(size=DIM).astype(np.float32)
    top = db.dsq(q, "/x/", k=5, precision="int8").ids[0]
    for eid in top[:2]:
        db.delete(int(eid))
    after = db.dsq(q, "/x/", k=5, precision="int8").ids[0]
    assert not (set(after.tolist()) & set(int(x) for x in top[:2]))


# ----------------------------------------------------- planner + accounting
def test_planner_precision_per_group():
    # calibration=False: this test asserts the hand-set planner internals
    # (a measured artifact may legitimately flip int8 -> fp32)
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi",
                           calibration=False)
    paths = ["/broad/"] * 900 + ["/narrow/"] * 20
    db.ingest(RNG.normal(size=(920, DIM)).astype(np.float32), paths)
    db.build_ann("flat")
    planner = db.planner()
    from repro.core.interface import normalize_batch
    acct = BatchAccounting()
    groups = planner.plan(db.namespaces["fs"], len(db.store),
                          normalize_batch(["/broad/", "/narrow/"], True,
                                          None),
                          k=10, acct=acct, precision="int8")
    by_path = {str(g.key.path): g for g in groups}
    broad = by_path[[p for p in by_path if "broad" in p][0]]
    narrow = by_path[[p for p in by_path if "narrow" in p][0]]
    assert broad.plan == "scan" and broad.precision == "int8"
    # 20 candidates < rescore window (40): int8 phase keeps them all, so
    # the planner leaves the group on the exact fp32 gather
    assert narrow.plan == "gather" and narrow.precision == "fp32"
    assert acct.precision_groups == {"int8": 1, "fp32": 1}


def test_batch_accounting_quantized_terms():
    # calibration=False: rescore_candidates == 6 * 40 assumes the hand-set
    # rescore factor and no precision flips
    db = DirectoryVectorDB(dim=DIM, scope_strategy="triehi",
                           calibration=False)
    db.ingest(RNG.normal(size=(1200, DIM)).astype(np.float32),
              ["/a/"] * 600 + ["/b/"] * 600)
    db.build_ann("flat")
    q = RNG.normal(size=(6, DIM)).astype(np.float32)
    res = db.dsq_batch(q, ["/a/", "/b/", "/", "/a/", "/b/", "/"], k=10,
                       precision="int8")
    acct = res[0].batch
    assert acct.db_bytes_fp32 == db.store.nbytes()
    assert acct.db_bytes_int8 == db.store.q_nbytes()
    assert acct.db_bytes_int8 < 0.30 * acct.db_bytes_fp32
    # 3 unique scan scopes x 2 requests each x rescore_k=40
    assert acct.rescore_candidates == 6 * 40
    assert acct.precision_groups.get("int8") == 3
    # default-precision batches carry no quantized terms
    res_fp = db.dsq_batch(q, ["/a/"] * 6, k=10)
    assert res_fp[0].batch.db_bytes_int8 == 0
    assert res_fp[0].batch.rescore_candidates == 0
    assert "int8" not in res_fp[0].batch.precision_groups


def test_dsq_rejects_unknown_precision():
    db = DirectoryVectorDB(dim=DIM)
    db.ingest(RNG.normal(size=(10, DIM)).astype(np.float32), ["/a/"] * 10)
    db.build_ann("flat")
    q = RNG.normal(size=DIM).astype(np.float32)
    with pytest.raises(ValueError, match="precision"):
        db.dsq(q, "/a/", precision="int4")
    with pytest.raises(ValueError, match="precision"):
        db.dsq_batch(q[None, :], ["/a/"], precision="fp16")


def test_serving_surfaces_quantized_stats():
    from repro.serving.rag import ContextDatabase, RAGConfig
    # calibration=False: the rescore_candidates floor assumes the int8
    # request is not measured-upgraded to fp32
    ctx = ContextDatabase(dim=DIM, calibration=False)
    for i in range(300):
        ctx.add_context(RNG.normal(size=DIM).astype(np.float32),
                        f"/docs/{i % 3}/", "L0",
                        np.arange(4) + i)
    ctx.build("flat")
    cfg = RAGConfig(k=5, precision="int8")
    hits, stats = ctx.retrieve(RNG.normal(size=DIM).astype(np.float32),
                               "/docs/", cfg)
    assert len(hits) == 5
    assert stats["db_bytes_int8"] < 0.30 * stats["db_bytes_fp32"]
    assert stats["rescore_candidates"] >= 20
