"""Recall@10 against brute-force ground truth on the released-dataset twins
(smoke scale), per strategy: the exact executors (flat, sharded) must achieve
recall 1.0, the approximate ones (ivf, pg) >= 0.95.

Ground truth comes from ``datasets.dirgen.brute_force_ground_truth`` (exact
scoped top-k, the paper's GT procedure). The exact-recall check is
tie-tolerant: an id swapped out for an equal-scoring one at the k boundary
still counts (GT is computed in numpy, the executors in XLA — low-bit score
differences must not flip the assertion)."""
import numpy as np
import pytest

from repro.core import STRATEGIES
from repro.datasets import brute_force_ground_truth, make_arxiv_dir, \
    make_wiki_dir
from repro.vectordb import DirectoryVectorDB

K = 10
DIM = 24
SCALE = 0.0003
N_QUERIES = 24


def _dataset(name):
    if name == "wiki":
        return make_wiki_dir(scale=SCALE, dim=DIM, n_queries=N_QUERIES,
                             seed=0)
    return make_arxiv_dir(scale=SCALE, dim=DIM, n_queries=N_QUERIES, seed=1)


def _recall(ds, gt, db, executor, **params):
    """Mean recall@K over queries with a non-empty scope; tie-tolerant
    (a missed GT id whose score equals the worst returned score counts)."""
    hits = total = 0
    for qi, (q, anchor, rec) in enumerate(
            zip(ds.queries, ds.query_anchors, ds.query_recursive)):
        want = gt[qi][gt[qi] >= 0]
        if len(want) == 0:
            continue
        res = db.dsq(q, anchor, k=K, recursive=bool(rec), executor=executor,
                     **params)
        got = {int(i) for i in res.ids[0] if int(i) >= 0}
        row_hits = len(set(int(w) for w in want) & got)
        if row_hits < len(want) and got:
            worst = float(np.min(res.scores[0][np.isfinite(res.scores[0])]))
            for w in set(int(w) for w in want) - got:
                s = float(ds.vectors[w] @ q)
                if abs(s - worst) < 1e-5:
                    row_hits += 1            # k-boundary score tie
        hits += row_hits
        total += len(want)
    assert total > 0
    return hits / total


@pytest.mark.parametrize("ds_name", ["wiki", "arxiv"])
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_recall_per_strategy(ds_name, strategy):
    ds = _dataset(ds_name)
    gt = brute_force_ground_truth(ds, k=K)
    db = DirectoryVectorDB(dim=DIM, scope_strategy=strategy)
    db.ingest(ds.vectors, ds.entry_paths,
              namespaces=ds.extra_namespaces or None)
    db.build_ann("flat")
    db.build_ann("sharded")
    db.build_ann("ivf", n_lists=8)
    db.build_ann("pg", max_degree=12, ef_construction=48)

    assert _recall(ds, gt, db, "flat") == 1.0
    assert _recall(ds, gt, db, "sharded") == 1.0
    assert _recall(ds, gt, db, "ivf", nprobe=7) >= 0.95
    assert _recall(ds, gt, db, "pg", ef_search=128) >= 0.95

    # int8 two-phase (quantized scan/gather -> exact fp32 rescore): the
    # exact executors stay near-exact through the default rescore window,
    # the approximate ones keep their fp32 floors
    assert _recall(ds, gt, db, "flat", precision="int8") >= 0.99
    assert _recall(ds, gt, db, "sharded", precision="int8") >= 0.99
    assert _recall(ds, gt, db, "ivf", nprobe=7, precision="int8") >= 0.95
    assert _recall(ds, gt, db, "pg", ef_search=128,
                   precision="int8") >= 0.95

    # pq two-phase (uint8 ADC scan/gather -> exact fp32 rescore): coarser
    # codes than int8, so the floors are the issue's gates — >= 0.95 for
    # the exact executors through the default rescore window, >= 0.90 for
    # the approximate ones
    assert _recall(ds, gt, db, "flat", precision="pq") >= 0.95
    assert _recall(ds, gt, db, "sharded", precision="pq") >= 0.95
    assert _recall(ds, gt, db, "ivf", nprobe=7, precision="pq") >= 0.90
    assert _recall(ds, gt, db, "pg", ef_search=128, precision="pq") >= 0.90
