"""Cross-validation of the three scope-resolution strategies (§III–IV).

The defining property of the design space: PE-ONLINE, PE-OFFLINE and TRIEHI
are *interchangeable implementations of the same semantics*. A random op
sequence (insert/delete/mkdir/move/merge + resolve) must keep all three in
exact agreement, and every structural invariant (ancestor materialization,
the TrieHI Eq. 1 aggregate) must hold afterwards.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import STRATEGIES, make_scope_index
from repro.core import paths as P

SEGS = ["a", "b", "c", "d"]

path_st = st.lists(st.sampled_from(SEGS), min_size=0, max_size=4).map(tuple)


class Op:
    def __init__(self, kind, **kw):
        self.kind = kind
        self.kw = kw

    def __repr__(self):
        return f"Op({self.kind}, {self.kw})"


ops_st = st.lists(
    st.one_of(
        st.tuples(st.just("insert"), st.integers(0, 99), path_st),
        st.tuples(st.just("delete"), st.integers(0, 99)),
        st.tuples(st.just("mkdir"), path_st),
        st.tuples(st.just("move"), path_st, path_st),
        st.tuples(st.just("merge"), path_st, path_st),
        st.tuples(st.just("remove"), path_st),
    ),
    max_size=30)


def apply_all(indexes, op):
    """Apply op to every index; all must agree on success/failure (and for
    remove, on the removed entry-id set)."""
    results = []
    removed_sets = []
    for idx in indexes:
        try:
            kind = op[0]
            if kind == "insert":
                idx.insert(op[1], op[2])
            elif kind == "delete":
                idx.delete(op[1])
            elif kind == "mkdir":
                idx.mkdir(op[1])
            elif kind == "move":
                idx.move(op[1], op[2])
            elif kind == "merge":
                idx.merge(op[1], op[2])
            elif kind == "remove":
                removed_sets.append(set(idx.remove(op[1]).to_array().tolist()))
            results.append("ok")
        except (KeyError, ValueError) as e:
            results.append(type(e).__name__)
    assert len(set(results)) == 1, (op, results, "strategies disagree")
    assert len(set(map(frozenset, removed_sets))) <= 1, (op, removed_sets)


@settings(max_examples=60, deadline=None)
@given(ops_st, st.lists(path_st, max_size=6))
def test_strategies_agree_under_random_ops(ops, probe_paths):
    indexes = [make_scope_index(n) for n in STRATEGIES]
    inserted = {}
    for op in ops:
        if op[0] == "insert" and op[1] in inserted:
            continue  # re-inserting an id is app-level misuse; skip
        if op[0] == "delete" and op[1] not in inserted:
            continue
        apply_all(indexes, op)
        if op[0] == "insert":
            inserted[op[1]] = op[2]
        elif op[0] == "delete":
            inserted.pop(op[1], None)
        elif op[0] == "remove":
            # entries under the removed subtree are unbound everywhere
            inserted = {eid: p for eid, p in inserted.items()
                        if indexes[0].entry_dir(eid) is not None}
    for idx in indexes:
        idx.check_invariants()
    # all resolutions agree on every probe path, recursive + non-recursive
    for path in list(probe_paths) + [()]:
        for recursive in (True, False):
            sets = [set(idx.resolve(path, recursive=recursive)
                        .to_array().tolist()) for idx in indexes]
            assert sets[0] == sets[1] == sets[2], (path, recursive, sets)
    # catalog agreement: every entry reports the same current directory
    dirs = [{eid: idx.entry_dir(eid) for eid in inserted} for idx in indexes]
    assert dirs[0] == dirs[1] == dirs[2]


@pytest.mark.parametrize("name", list(STRATEGIES))
def test_paper_running_example(name):
    """Figure 2/3/4/5 walk-through: /HR, /Dept_A, /Dept_B, /Archive."""
    idx = make_scope_index(name)
    idx.insert(1, "/HR/")
    idx.insert(2, "/HR/Policies/")
    idx.insert(5, "/Dept_A/")
    idx.insert(8, "/Dept_A/OKR/")
    idx.insert(9, "/Dept_B/OKR/")
    idx.insert(7, "/Archive/HR/")
    # DSQ
    assert set(idx.resolve("/HR/", True)) == {1, 2}
    assert set(idx.resolve("/HR/", False)) == {1}
    assert set(idx.resolve("/Dept_A/", True)) == {5, 8}
    assert set(idx.resolve("/Archive/", True)) == {7}
    assert set(idx.resolve("/nonexistent/", True)) == set()
    # MOVE /Dept_A/ under /Dept_B/
    idx.move("/Dept_A/", "/Dept_B/")
    assert set(idx.resolve("/Dept_B/", True)) == {5, 8, 9}
    assert not idx.has_dir("/Dept_A/")
    assert idx.entry_dir(8) == ("Dept_B", "Dept_A", "OKR")
    # move back, then MERGE with OKR conflict: doc_8 + doc_9 unioned
    idx.move("/Dept_B/Dept_A/", "/")
    idx.merge("/Dept_A/", "/Dept_B/")
    assert set(idx.resolve("/Dept_B/OKR/", True)) == {8, 9}
    assert set(idx.resolve("/Dept_B/", False)) == {5}
    idx.check_invariants()


@pytest.mark.parametrize("name", list(STRATEGIES))
def test_move_rejects_cycle_and_conflict(name):
    idx = make_scope_index(name)
    idx.insert(1, "/a/b/")
    idx.insert(2, "/c/")
    with pytest.raises(ValueError):
        idx.move("/a/", "/a/b/")          # into own subtree
    idx.mkdir("/c/a/")
    with pytest.raises(ValueError):
        idx.move("/a/", "/c/")            # name conflict -> use merge
    idx.check_invariants()


@pytest.mark.parametrize("name", list(STRATEGIES))
def test_exclusion_query(name):
    idx = make_scope_index(name)
    idx.insert(1, "/docs/v2/")
    idx.insert(2, "/archive/v1/")
    idx.insert(3, "/docs/")
    got = idx.resolve_exclusion("/", ["/archive/"], recursive=True)
    assert set(got) == {1, 3}


@pytest.mark.parametrize("name", list(STRATEGIES))
def test_deep_chain_costs_shape(name):
    """Sanity on the cost *shape*: resolving deep anchors touches the
    expected number of keys (m_q for PE-ONLINE, O(t) for TrieHI)."""
    from repro.core.interface import ResolveStats
    idx = make_scope_index(name)
    depth = 12
    for d in range(depth):
        idx.insert(d, "/" + "/".join(f"s{i}" for i in range(d + 1)) + "/")
    stats = ResolveStats()
    got = idx.resolve("/s0/", recursive=True, stats=stats)
    assert set(got) == set(range(depth))
    if name == "pe_online":
        assert stats.subpath_keys == depth      # enumerated whole subtree
    if name == "triehi":
        assert stats.node_visits == 2           # root + s0
    if name == "pe_offline":
        assert stats.posting_fetches == 1       # one materialized lookup


@pytest.mark.parametrize("name", list(STRATEGIES))
def test_wildcard_pattern_dsq(name):
    """Beyond-paper: §IV-A derived path patterns (the paper's named future
    work). All strategies agree; TrieHI answers by branch-pruned traversal."""
    idx = make_scope_index(name)
    idx.insert(1, "/users/u0/sessions/s0/")
    idx.insert(2, "/users/u1/sessions/s0/")
    idx.insert(3, "/users/u1/sessions/s1/")
    idx.insert(4, "/other/u9/sessions/s0/")
    idx.insert(5, "/users/u1/sessions/s1/deep/")
    assert set(idx.resolve_pattern("/users/*/sessions/s0/")) == {1, 2}
    assert set(idx.resolve_pattern("/users/u1/*/")) == {2, 3, 5}
    assert set(idx.resolve_pattern("/users/u1/sessions/*/",
                                   recursive=False)) == {2, 3}
    assert set(idx.resolve_pattern("/nope/*/")) == set()


@settings(max_examples=25, deadline=None)
@given(ops_st, st.lists(st.sampled_from(SEGS + ["*"]),
                        min_size=1, max_size=3).map(tuple))
def test_wildcard_strategies_agree(ops, pattern):
    indexes = [make_scope_index(n) for n in STRATEGIES]
    inserted = set()
    for op in ops:
        if op[0] == "insert" and op[1] in inserted:
            continue
        if op[0] == "delete" and op[1] not in inserted:
            continue
        apply_all(indexes, op)
        if op[0] == "insert":
            inserted.add(op[1])
        elif op[0] == "delete":
            inserted.discard(op[1])
    sets = [set(idx.resolve_pattern(pattern).to_array().tolist())
            for idx in indexes]
    assert sets[0] == sets[1] == sets[2], (pattern, sets)
