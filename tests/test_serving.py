"""Continuous-batching serving front end (serving/scheduler.py).

Contract under test: the scheduler is an *admission/occupancy* layer, never
a semantic one — scheduler-coalesced results are bit-identical to a direct
``dsq_batch`` of the same batch on every executor and precision, including
immediately after a racing ``dsm_batch`` (staged masks epoch-invalidate
rather than serve stale scopes). Around that: flush policy (size vs SLO
deadline), weighted-fair admission under a flooding tenant, typed
backpressure at queue capacity, seeded arrival-process determinism, and the
serving metrics/accounting surface.
"""
import threading
import time

import numpy as np
import pytest

from repro.datasets import make_wiki_dir
from repro.serving import ContextDatabase, RAGConfig
from repro.serving.scheduler import (AdmissionError, ContinuousScheduler,
                                     ScheduledDSQ, SchedulerConfig,
                                     open_loop_arrivals)
from repro.vectordb import DirectoryVectorDB
from repro.vectordb.planner import BatchAccounting

EXECUTORS = ("flat", "ivf", "pg", "sharded")
PRECISIONS = ("fp32", "int8", "pq")
K = 8


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_dir(scale=0.002, dim=32, n_queries=24, seed=7)


@pytest.fixture(scope="module")
def db(wiki):
    db = DirectoryVectorDB(dim=32, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=8)
    db.build_ann("pg", max_degree=8, ef_construction=16)
    db.build_ann("sharded")
    return db


def _requests(wiki, n):
    paths = [(wiki.query_anchors[i % 6] or "/") for i in range(n)]
    paths[0] = "/"
    rec = [bool(wiki.query_recursive[i % 6]) for i in range(n)]
    return wiki.queries[:n], paths, rec


class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def _noop_sched(cfg, clock=None):
    return ContinuousScheduler(lambda payloads, staged: list(payloads),
                               cfg=cfg, clock=clock)


# ------------------------------------------------------------ flush policy
def test_flush_due_size_vs_deadline():
    clk = _FakeClock()
    s = _noop_sched(SchedulerConfig(max_batch=4, max_wait_ms=10.0), clock=clk)
    assert s._flush_due() is None                      # nothing pending
    for _ in range(3):
        s.submit("p")
    assert s._flush_due() is None                      # under size, under SLO
    clk.t += 0.0099
    assert s._flush_due() is None                      # 9.9 ms < 10 ms budget
    clk.t += 0.0002
    assert s._flush_due() == "deadline"                # oldest exhausted SLO
    s.submit("p")
    assert s._flush_due() == "size"                    # size wins at capacity
    with s._cond:
        batch = s._form_batch()
    assert [r.seq for r in batch] == [0, 1, 2, 3]      # FIFO prefix
    assert s._flush_due() is None


def test_flush_reason_reaches_tickets():
    s = _noop_sched(SchedulerConfig(max_batch=2, max_wait_ms=5.0))
    with s:
        t1 = s.submit("a")
        t2 = s.submit("b")
        assert t1.result(5.0) == "a" and t2.result(5.0) == "b"
        assert t1.flush == "size" and t1.batch_size == 2
        t3 = s.submit("c")                             # alone -> SLO flush
        assert t3.result(5.0) == "c"
    assert t3.flush in ("deadline", "drain")
    assert t3.batch_size == 1


# ----------------------------------------------------- weighted-fair admission
def test_fairness_under_flooding_tenant():
    s = _noop_sched(SchedulerConfig(max_batch=8, max_wait_ms=1e4,
                                    queue_capacity=1000))
    for _ in range(100):
        s.submit("flood", tenant="a")                  # tenant a floods
    for _ in range(4):
        s.submit("fair", tenant="b")
    with s._cond:
        batch = s._form_batch()
    counts = {t: sum(1 for r in batch if r.tenant == t) for t in ("a", "b")}
    assert len(batch) == 8
    assert counts["b"] == 4                            # equal-weight share
    assert counts["a"] == 4


def test_weighted_shares():
    s = _noop_sched(SchedulerConfig(max_batch=8, max_wait_ms=1e4,
                                    queue_capacity=1000,
                                    tenant_weights={"a": 3.0, "b": 1.0}))
    for _ in range(50):
        s.submit("x", tenant="a")
        s.submit("y", tenant="b")
    with s._cond:
        batch = s._form_batch()
    counts = {t: sum(1 for r in batch if r.tenant == t) for t in ("a", "b")}
    assert counts["a"] == 6 and counts["b"] == 2       # 3:1 of 8 slots


def test_batch_sorted_by_admission_seq():
    s = _noop_sched(SchedulerConfig(max_batch=6, max_wait_ms=1e4))
    for i in range(3):
        s.submit(i, tenant="a")
        s.submit(i, tenant="b")
    with s._cond:
        batch = s._form_batch()
    assert [r.seq for r in batch] == sorted(r.seq for r in batch)


# ------------------------------------------------------------- backpressure
def test_backpressure_typed_rejection():
    s = _noop_sched(SchedulerConfig(max_batch=8, max_wait_ms=1e4,
                                    queue_capacity=3))
    for _ in range(3):
        s.submit("ok", tenant="t")
    with pytest.raises(AdmissionError) as ei:
        s.submit("overflow", tenant="t")
    assert ei.value.tenant == "t"
    assert ei.value.queued == 3 and ei.value.capacity == 3
    s.submit("other-tenant-unaffected", tenant="u")    # per-tenant bound
    snap = s.metrics.snapshot()
    assert snap["rejected"] == 1
    assert snap["submitted"] == 4
    assert snap["shed_rate"] == pytest.approx(1 / 5)


# ----------------------------------------------------- arrival process
def test_open_loop_arrivals_seeded_determinism():
    a = open_loop_arrivals(50.0, 256, seed=3)
    b = open_loop_arrivals(50.0, 256, seed=3)
    c = open_loop_arrivals(50.0, 256, seed=4)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)                     # cumulative offsets
    assert a[-1] / 256 == pytest.approx(1 / 50.0, rel=0.25)


# ------------------------------------------------------------- bit-identity
@pytest.mark.parametrize("executor", EXECUTORS)
def test_scheduled_bit_identical_to_direct(executor, db, wiki):
    """pump() reproduces the exact coalesced batch, so ids AND score bits
    must match the direct dsq_batch on every executor x precision."""
    n = 12
    queries, paths, rec = _requests(wiki, n)
    for precision in PRECISIONS:
        rescore = 4 * K if precision != "fp32" else None
        direct = db.dsq_batch(queries, paths, k=K, recursive=rec,
                              executor=executor, precision=precision,
                              rescore_k=rescore)
        sdsq = ScheduledDSQ(db, k=K, executor=executor, precision=precision,
                            rescore_k=rescore,
                            cfg=SchedulerConfig(max_batch=n, max_wait_ms=1e4))
        tickets = [sdsq.submit(queries[i], paths[i], recursive=rec[i])
                   for i in range(n)]
        assert sdsq.pump() == n
        for i, t in enumerate(tickets):
            res = t.result(30.0)
            np.testing.assert_array_equal(res.ids[0], direct[i].ids[0],
                                          err_msg=f"{executor}/{precision}")
            np.testing.assert_array_equal(res.scores[0], direct[i].scores[0],
                                          err_msg=f"{executor}/{precision}")


@pytest.mark.parametrize("executor", ["flat", "sharded"])
def test_bit_identity_after_racing_dsm(executor, wiki):
    """DSM lands between staging and execution: the staged masks were
    resolved under pre-DSM epoch tokens, so execution must re-resolve (not
    serve the stale scope) and match a fresh direct dsq_batch."""
    db = DirectoryVectorDB(dim=32, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    db.build_ann("sharded")
    n = 8
    queries, paths, rec = _requests(wiki, n)
    src = next(p for p in paths if p != "/")
    sdsq = ScheduledDSQ(db, k=K, executor=executor,
                        cfg=SchedulerConfig(max_batch=n, max_wait_ms=1e4))
    sched = sdsq.scheduler
    tickets = [sdsq.submit(queries[i], paths[i], recursive=rec[i])
               for i in range(n)]
    with sched._cond:
        batch = sched._form_batch()
    staged, stage_s = sched._do_stage(batch)           # pre-DSM masks staged
    db.dsm_batch([("move", src, "/moved/")])           # racing maintenance
    sched._run_batch(batch, staged, stage_s, "test")
    direct = db.dsq_batch(queries, paths, k=K, recursive=rec,
                          executor=executor)           # post-DSM truth
    for i, t in enumerate(tickets):
        res = t.result(30.0)
        np.testing.assert_array_equal(res.ids[0], direct[i].ids[0])
        np.testing.assert_array_equal(res.scores[0], direct[i].scores[0])


# ----------------------------------------------------- threaded end to end
def test_threaded_end_to_end_matches_direct(db, wiki):
    """Threaded collector/executor pair under concurrent submitters: every
    ticket resolves, and (flat executor: per-request results independent of
    batch composition) each equals its direct single-request dsq."""
    n = 24
    queries, paths, rec = _requests(wiki, n)
    sdsq = ScheduledDSQ(db, k=K, cfg=SchedulerConfig(max_batch=6,
                                                     max_wait_ms=5.0))
    tickets = [None] * n
    with sdsq:
        def client(lo, hi):
            for i in range(lo, hi):
                tickets[i] = sdsq.submit(queries[i], paths[i],
                                         recursive=rec[i])
        threads = [threading.Thread(target=client, args=(j, j + 8))
                   for j in range(0, n, 8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = [t.result(30.0) for t in tickets]
    for i, res in enumerate(results):
        direct = db.dsq(queries[i], paths[i], k=K, recursive=rec[i])
        np.testing.assert_array_equal(res.ids[0], direct.ids[0])
        np.testing.assert_array_equal(res.scores[0], direct.scores[0])
    snap = sdsq.metrics.snapshot()
    assert snap["completed"] == n
    assert snap["batches"] >= n // 6                   # coalesced, not 1:1
    assert snap["accounting"]["sched_batches"] == snap["batches"]


def test_execute_failure_fans_out_to_tickets():
    def boom(payloads, staged):
        raise ValueError("batch died")

    s = ContinuousScheduler(boom, cfg=SchedulerConfig(max_batch=4,
                                                      max_wait_ms=1e4))
    t1, t2 = s.submit("a"), s.submit("b")
    assert s.pump() == 2
    for t in (t1, t2):
        with pytest.raises(ValueError, match="batch died"):
            t.result(5.0)


# --------------------------------------------------- metrics + accounting
def test_batch_accounting_merge_and_snapshot_reset():
    a, b = BatchAccounting(), BatchAccounting()
    a.batch_size, b.batch_size = 3, 5
    a.plan_groups["scan"], b.plan_groups["scan"] = 1, 2
    b.plan_groups["gather"] = 4
    a.sched_batches, b.sched_batches = 1, 1
    a.sched_queue_ns, b.sched_queue_ns = 100, 50
    a.sched_arrival_ns, b.sched_arrival_ns = 900, 700
    a.resolve_stats.stage_ns["resolve"] = 10
    b.resolve_stats.stage_ns["resolve"] = 5
    a.merge(b)
    assert a.batch_size == 8
    assert a.plan_groups == {"scan": 3, "gather": 4}
    assert a.sched_batches == 2 and a.sched_queue_ns == 150
    assert a.sched_arrival_ns == 700                   # earliest arrival wins
    assert a.resolve_stats.stage_ns["resolve"] == 15
    snap = a.snapshot(reset=True)
    assert snap["batch_size"] == 8
    assert snap["plan_groups"] == {"scan": 3, "gather": 4}
    assert a.batch_size == 0 and a.plan_groups == {}   # reset for next window
    assert a.sched_batches == 0


def test_metrics_window_reset(db, wiki):
    queries, paths, rec = _requests(wiki, 4)
    sdsq = ScheduledDSQ(db, k=K, cfg=SchedulerConfig(max_batch=4,
                                                     max_wait_ms=1e4))
    for i in range(4):
        sdsq.submit(queries[i], paths[i], recursive=rec[i])
    sdsq.pump()
    snap = sdsq.metrics.snapshot(reset=True)
    assert snap["completed"] == 4 and snap["batches"] == 1
    assert snap["occupancy"] == pytest.approx(1.0)
    assert snap["p99_ms"] >= snap["p50_ms"] > 0
    fresh = sdsq.metrics.snapshot()
    assert fresh["completed"] == 0 and fresh["batches"] == 0


# ----------------------------------------------------------- RAG async API
def test_context_database_async_parity_and_stats(wiki):
    ctx = ContextDatabase(dim=32)
    rng = np.random.default_rng(0)
    for i in range(min(120, len(wiki.entry_paths))):
        ctx.add_context(wiki.vectors[i], wiki.entry_paths[i],
                        ("L0", "L1", "L2")[i % 3],
                        rng.integers(0, 99, size=12))
    ctx.build("flat")
    cfg = RAGConfig(k=5)
    n = 6
    queries, paths, _ = _requests(wiki, n)
    ctx.start_serving(cfg, SchedulerConfig(max_batch=n, max_wait_ms=50.0))
    with pytest.raises(RuntimeError):
        ctx.start_serving(cfg)                         # double start refused
    tickets = [ctx.submit_retrieve(queries[i], paths[i]) for i in range(n)]
    async_res = [t.result(30.0) for t in tickets]
    sync_res = ctx.retrieve_batch(queries, paths, cfg)
    for (ha, sa), (hs, ss) in zip(async_res, sync_res):
        assert [h.entry_id for h in ha] == [h.entry_id for h in hs]
        assert sa["scope_size"] == ss["scope_size"]
        assert "sched_occupancy" in sa                 # scheduler terms added
        assert "sched_occupancy" not in ss             # direct path untouched
    snap = ctx.serving_stats(reset=True)
    assert snap["completed"] == n
    assert snap["qps"] > 0
    ctx.stop_serving()
    assert ctx._serving is None
    with pytest.raises(RuntimeError):
        ctx.serving_stats()
