"""ShardedExecutor serving tier: plan parity with the flat path, the
device-resident scope table (token hits, DSM delta word-range patching),
incremental re-shard accounting, and the multi-scope dry-run specs.

Single-device cases run in-process (the executor degenerates to a 1-shard
mesh but exercises the full shard_map path); true multi-shard semantics run
in a subprocess with 8 simulated host devices (``multidevice`` marker, the
same pattern as ``test_distributed.py`` — the main pytest process must keep
seeing exactly 1 device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _mixed_db(strategy="triehi", n=600, d=16, seed=0, calibration=None):
    from repro.vectordb import DirectoryVectorDB
    rng = np.random.default_rng(seed)
    paths = [f"/a/b{i % 7}/" if i % 3 else "/a/" for i in range(n)]
    db = DirectoryVectorDB(dim=d, scope_strategy=strategy,
                           calibration=calibration)
    db.ingest(rng.normal(size=(n, d)).astype(np.float32), paths)
    db.build_ann("flat")
    db.build_ann("sharded")
    return db, rng


def _assert_parity(res_a, res_b):
    for a, b in zip(res_a, res_b):
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.ids, b.ids)
        assert a.scope_size == b.scope_size


@pytest.mark.parametrize("strategy", ["triehi", "pe_online", "pe_offline"])
def test_sharded_batch_matches_flat(strategy):
    db, rng = _mixed_db(strategy)
    B, d = 12, 16
    q = rng.normal(size=(B, d)).astype(np.float32)
    scopes = [["/a/", "/a/b1/", "/", "/a/b2/"][i % 4] for i in range(B)]
    rec = [bool(i % 3) for i in range(B)]
    exc = [["/a/b1/"] if i % 5 == 0 else [] for i in range(B)]
    _assert_parity(db.dsq_batch(q, scopes, k=5, recursive=rec, exclude=exc,
                                executor="flat"),
                   db.dsq_batch(q, scopes, k=5, recursive=rec, exclude=exc,
                                executor="sharded"))
    # per-request front door mirrors FlatExecutor.search too
    for i in range(B):
        a = db.dsq(q[i], scopes[i], k=5, executor="flat")
        b = db.dsq(q[i], scopes[i], k=5, executor="sharded")
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.ids, b.ids)


def test_sharded_scope_table_hits_and_accounting():
    db, rng = _mixed_db()
    ex = db.executors["sharded"]
    B = 8
    q = rng.normal(size=(B, 16)).astype(np.float32)
    scopes = ["/a/", "/"] * (B // 2)
    r1 = db.dsq_batch(q, scopes, k=5, executor="sharded")
    acct = r1[0].batch
    assert acct.n_shards == ex.n_shards >= 1
    assert acct.shard_mask_bytes > 0          # first batch uploads the masks
    assert acct.collective_bytes > 0
    m0 = ex.mask_bytes_uploaded
    r2 = db.dsq_batch(q, scopes, k=5, executor="sharded")
    assert ex.mask_bytes_uploaded == m0       # token-validated slot hits
    assert r2[0].batch.shard_mask_hits == r2[0].batch.plan_groups.get("scan")
    assert r2[0].batch.shard_mask_bytes == 0


def test_sharded_table_grows_past_slot_capacity():
    """A batch with more unique scan scopes than table slots must grow the
    table (a same-batch LRU eviction would rank requests against the wrong
    mask) and stay bit-identical to flat."""
    db, rng = _mixed_db()
    db.build_ann("sharded", table_slots=2)
    ex = db.executors["sharded"]
    B = 12
    q = rng.normal(size=(B, 16)).astype(np.float32)
    paths = ["/"] * B
    exc = [[f"/a/b{i % 6}/"] for i in range(B)]   # 6 unique broad scopes
    _assert_parity(db.dsq_batch(q, paths, k=5, exclude=exc,
                                executor="flat"),
                   db.dsq_batch(q, paths, k=5, exclude=exc,
                                executor="sharded"))
    assert ex.table_slots >= 6


def test_sharded_dsm_delta_patches_resident_masks():
    db, rng = _mixed_db()
    ex = db.executors["sharded"]
    B = 8
    q = rng.normal(size=(B, 16)).astype(np.float32)
    db.dsq_batch(q, ["/a/", "/"] * (B // 2), k=5, executor="sharded")
    m0, p0 = ex.mask_bytes_uploaded, ex.masks_patched
    db.dsm_batch([("mkdir", "/z/"), ("move", "/a/b1/", "/z/")])
    # the /a/ and / slots lie on the vacated/gaining chains -> patched in
    # place with a word-range scatter, never re-uploaded
    assert ex.masks_patched > p0
    assert ex.mask_bytes_patched > 0
    _assert_parity(db.dsq_batch(q, ["/a/", "/"] * (B // 2), k=5,
                                executor="flat"),
                   db.dsq_batch(q, ["/a/", "/"] * (B // 2), k=5,
                                executor="sharded"))
    assert ex.mask_bytes_uploaded == m0, \
        "patched slots must be served without re-upload"


def test_sharded_view_incremental_resharding():
    db, rng = _mixed_db(n=600)
    ex = db.executors["sharded"]
    q = rng.normal(size=(4, 16)).astype(np.float32)
    db.dsq_batch(q, ["/"] * 4, k=5, executor="sharded")
    cap0, r0, b0 = ex.view.cap, ex.view.reshards, ex.view.db_bytes_uploaded
    # growth within padded capacity: only the new rows travel
    n_new = cap0 - len(db.store)
    assert n_new > 0
    db.ingest(rng.normal(size=(n_new, 16)).astype(np.float32),
              ["/a/"] * n_new)
    _assert_parity(db.dsq_batch(q, ["/", "/a/"] * 2, k=5, executor="flat"),
                   db.dsq_batch(q, ["/", "/a/"] * 2, k=5,
                                executor="sharded"))
    assert ex.view.reshards == r0
    assert ex.view.db_bytes_uploaded - b0 == n_new * 16 * 4
    # growth past capacity: one amortized-doubling re-shard
    db.ingest(rng.normal(size=(8, 16)).astype(np.float32), ["/a/"] * 8)
    _assert_parity(db.dsq_batch(q, ["/", "/a/"] * 2, k=5, executor="flat"),
                   db.dsq_batch(q, ["/", "/a/"] * 2, k=5,
                                executor="sharded"))
    assert ex.view.reshards == r0 + 1
    assert ex.view.cap == 2 * cap0


def test_sharded_alive_mask_patches_incrementally():
    """A tombstone must patch only the alive-mask words it touches, not
    rebuild/re-upload the whole packed mask."""
    db, rng = _mixed_db()
    ex = db.executors["sharded"]
    q = rng.normal(size=(4, 16)).astype(np.float32)
    db.dsq_batch(q, ["/"] * 4, k=5, executor="sharded")
    full = ex.view.n_words * 4
    a0 = ex.view.alive_bytes_uploaded
    assert a0 >= full                      # initial full upload happened
    db.delete(1)
    _assert_parity(db.dsq_batch(q, ["/"] * 4, k=5, executor="flat"),
                   db.dsq_batch(q, ["/"] * 4, k=5, executor="sharded"))
    delta = ex.view.alive_bytes_uploaded - a0
    assert 0 < delta < full, (delta, full)


def test_sharded_tombstones_and_rmdir():
    db, rng = _mixed_db()
    q = rng.normal(size=(6, 16)).astype(np.float32)
    db.delete(0)
    db.delete(5)
    db.rmdir("/a/b3/")
    scopes = ["/", "/a/", "/a/b1/"] * 2
    _assert_parity(db.dsq_batch(q, scopes, k=5, executor="flat"),
                   db.dsq_batch(q, scopes, k=5, executor="sharded"))
    for r in db.dsq_batch(q, scopes, k=20, executor="sharded"):
        ids = r.ids[r.ids >= 0]
        assert 0 not in ids and 5 not in ids


def test_sharded_serving_rag_parity():
    from repro.serving.rag import ContextDatabase, RAGConfig
    rng = np.random.default_rng(3)
    d = 16
    ctx = ContextDatabase(dim=d)
    for i in range(120):
        path = f"/mem/s{i % 5}/" if i % 2 else "/mem/"
        vec = rng.normal(size=d).astype(np.float32)
        ctx.add_context(vec, path, "L0", np.arange(4, dtype=np.int32))
    ctx.build("flat")
    ctx.build("sharded")
    q = rng.normal(size=(4, d)).astype(np.float32)
    scopes = ["/mem/", "/mem/s1/", "/mem/", "/mem/s2/"]
    flat = ctx.retrieve_batch(q, scopes, RAGConfig(k=5, executor="flat"))
    shard = ctx.retrieve_batch(q, scopes, RAGConfig(k=5, executor="sharded"))
    for (ha, _), (hb, sb) in zip(flat, shard):
        assert [h.entry_id for h in ha] == [h.entry_id for h in hb]
        assert sb["n_shards"] >= 1
        assert "collective_bytes" in sb


def test_multi_scope_input_specs_shapes():
    import jax
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.distributed.search import multi_scope_search_input_specs
    mesh = make_mesh((1,), ("data",))
    (db, words, alive, sids, q), shardings = multi_scope_search_input_specs(
        mesh, n_total=256, dim=32, n_queries=6, n_scopes=3)
    assert db.shape == (256, 32) and db.dtype == jnp.float32
    assert words.shape == (3, 8) and words.dtype == jnp.uint32
    assert alive.shape == (8,) and alive.dtype == jnp.uint32
    assert sids.shape == (6,) and sids.dtype == jnp.int32
    assert q.shape == (6, 32) and q.dtype == jnp.float32
    assert len(shardings) == 5
    with pytest.raises(AssertionError):
        multi_scope_search_input_specs(mesh, n_total=100, dim=32,
                                       n_queries=6, n_scopes=3)


def test_dryrun_sharded_scan_lowers():
    """The batched sharded scan lowers/compiles from specs alone (the
    launch/dryrun.py viking-scan-batch path, at toy size on 1 device)."""
    import jax
    from repro.compat import make_mesh
    from repro.distributed.search import (make_sharded_batch_search,
                                          multi_scope_search_input_specs)
    mesh = make_mesh((1,), ("data",))
    fn = make_sharded_batch_search(mesh, 256, 32, 10)
    args, shardings = multi_scope_search_input_specs(mesh, 256, 32, 6, 3)
    with mesh:
        compiled = jax.jit(fn.__wrapped__ if hasattr(fn, "__wrapped__")
                           else fn, in_shardings=shardings).lower(
            *args).compile()
    assert compiled is not None


def test_sharded_int8_two_phase_matches_flat_int8():
    """sharded int8 (shard-local int8 scan + shard-merge + one global fp32
    rescore) must return the same top-k sets and fp32 scores as the flat
    int8 path, and — with an exhaustive rescore window — the exact fp32
    result."""
    # calibration=False: the quantized-byte accounting assumes the int8
    # request is not measured-upgraded to fp32
    db, rng = _mixed_db(calibration=False)
    B, d = 8, 16
    q = rng.normal(size=(B, d)).astype(np.float32)
    scopes = [["/a/", "/", "/a/b2/"][i % 3] for i in range(B)]
    exact = db.dsq_batch(q, scopes, k=5, executor="sharded")
    for rk in (64, len(db.store)):
        sh = db.dsq_batch(q, scopes, k=5, executor="sharded",
                          precision="int8", rescore_k=rk)
        fl = db.dsq_batch(q, scopes, k=5, executor="flat",
                          precision="int8", rescore_k=rk)
        for a, b in zip(sh, fl):
            assert (set(int(x) for x in a.ids[0])
                    == set(int(x) for x in b.ids[0]))
            np.testing.assert_allclose(np.sort(a.scores[0]),
                                       np.sort(b.scores[0]),
                                       rtol=1e-5, atol=1e-5)
    for a, b in zip(sh, exact):
        assert (set(int(x) for x in a.ids[0])
                == set(int(x) for x in b.ids[0]))
    acct = sh[0].batch
    assert acct.db_bytes_int8 and acct.rescore_candidates


# --------------------------------------------------------------- multidevice
@pytest.mark.multidevice
def test_sharded_int8_8dev():
    """8-shard int8 scan: per-shard top-r merge + global rescore equals the
    fp32 exact result under an exhaustive window, tombstones stay masked."""
    run_with_devices("""
        import numpy as np
        from repro.vectordb import DirectoryVectorDB
        rng = np.random.default_rng(5)
        db = DirectoryVectorDB(dim=16, scope_strategy="triehi")
        paths = [f"/a/b{i % 5}/" if i % 2 else "/c/" for i in range(900)]
        db.ingest(rng.normal(size=(900, 16)).astype(np.float32), paths)
        db.build_ann("flat")
        db.build_ann("sharded")
        assert db.executors["sharded"].n_shards == 8
        q = rng.normal(size=(6, 16)).astype(np.float32)
        scopes = [["/a/", "/", "/c/"][i % 3] for i in range(6)]
        exact = db.dsq_batch(q, scopes, k=5, executor="sharded")
        sh = db.dsq_batch(q, scopes, k=5, executor="sharded",
                          precision="int8", rescore_k=900)
        for a, b in zip(sh, exact):
            assert (set(int(x) for x in a.ids[0])
                    == set(int(x) for x in b.ids[0])), (a.ids, b.ids)
        # tombstoned rows never resurface from the int8 mesh scan
        dead = [int(x) for x in exact[1].ids[0][:2]]
        for eid in dead:
            db.delete(eid)
        after = db.dsq_batch(q, scopes, k=5, executor="sharded",
                             precision="int8", rescore_k=900)
        got = {int(x) for r in after for x in r.ids[0]}
        assert not (got & set(dead))
        print("ok")
    """)


@pytest.mark.multidevice
def test_sharded_batch_bit_identical_8dev():
    """The acceptance contract: on an 8-host-device mesh, dsq_batch
    executor='sharded' is bit-identical to the single-device flat batch
    path, including immediately after a dsm_batch of move/merge/remove ops
    with the shard-resident masks patched (not rebuilt)."""
    run_with_devices("""
        import numpy as np, jax
        from repro.vectordb import DirectoryVectorDB
        assert len(jax.devices()) == 8
        rng = np.random.default_rng(1)
        n, d, B = 2000, 32, 24
        paths = [f"/w/p{i%9}/" if i % 4 else "/w/" for i in range(n)]
        db = DirectoryVectorDB(dim=d, scope_strategy="triehi")
        db.ingest(rng.normal(size=(n, d)).astype(np.float32), paths)
        db.build_ann("flat"); db.build_ann("sharded")
        ex = db.executors["sharded"]
        assert ex.n_shards == 8
        q = rng.normal(size=(B, d)).astype(np.float32)
        scopes = [["/w/", "/w/p1/", "/", "/w/p3/", "/w/p4/"][i % 5]
                  for i in range(B)]
        rec = [bool(i % 3) for i in range(B)]
        rf = db.dsq_batch(q, scopes, k=10, recursive=rec, executor="flat")
        rs = db.dsq_batch(q, scopes, k=10, recursive=rec, executor="sharded")
        for a, b in zip(rf, rs):
            assert np.array_equal(a.scores, b.scores)
            assert np.array_equal(a.ids, b.ids)
        # DSM: shard-resident masks patch in place, results stay identical
        m0 = ex.mask_bytes_uploaded
        db.dsm_batch([("mkdir", "/x/"), ("move", "/w/p1/", "/x/"),
                      ("merge", "/w/p3/", "/w/p4/"), ("remove", "/w/p5/")])
        rf = db.dsq_batch(q, ["/w/", "/"] * (B // 2), k=10, executor="flat")
        rs = db.dsq_batch(q, ["/w/", "/"] * (B // 2), k=10,
                          executor="sharded")
        for a, b in zip(rf, rs):
            assert np.array_equal(a.scores, b.scores)
            assert np.array_equal(a.ids, b.ids)
        assert ex.masks_patched >= 1
        assert ex.mask_bytes_uploaded == m0, "survivors must not re-upload"
        print("8dev bit-identity OK", ex.stats())
    """)


@pytest.mark.multidevice
def test_sharded_ingest_reshard_8dev():
    run_with_devices("""
        import numpy as np, jax
        from repro.vectordb import DirectoryVectorDB
        rng = np.random.default_rng(7)
        d = 16
        db = DirectoryVectorDB(dim=d)
        db.ingest(rng.normal(size=(300, d)).astype(np.float32), ["/a/"] * 300)
        db.build_ann("flat"); db.build_ann("sharded")
        ex = db.executors["sharded"]
        q = rng.normal(size=(4, d)).astype(np.float32)
        db.dsq_batch(q, ["/"] * 4, k=5, executor="sharded")
        assert ex.view.cap % (32 * 8) == 0
        cap0, r0 = ex.view.cap, ex.view.reshards
        grow = cap0 - len(db.store)
        db.ingest(rng.normal(size=(grow, d)).astype(np.float32),
                  ["/a/"] * grow)
        rf = db.dsq_batch(q, ["/"] * 4, k=5, executor="flat")
        rs = db.dsq_batch(q, ["/"] * 4, k=5, executor="sharded")
        for a, b in zip(rf, rs):
            assert np.array_equal(a.scores, b.scores)
            assert np.array_equal(a.ids, b.ids)
        assert ex.view.reshards == r0          # in-place row scatter
        db.ingest(rng.normal(size=(1, d)).astype(np.float32), ["/a/"])
        db.dsq_batch(q, ["/"] * 4, k=5, executor="sharded")
        assert ex.view.reshards == r0 + 1      # amortized-doubling re-shard
        assert ex.view.cap == 2 * cap0
        print("8dev reshard OK")
    """)


@pytest.mark.multidevice
def test_dryrun_sharded_scan_compiles_8dev():
    run_with_devices("""
        import jax
        from repro.launch.mesh import make_mesh_for_devices
        from repro.distributed.search import (make_sharded_batch_search,
                                              multi_scope_search_input_specs)
        mesh = make_mesh_for_devices(model_parallelism=2)
        fn = make_sharded_batch_search(mesh, 2048, 64, 10)
        args, shardings = multi_scope_search_input_specs(mesh, 2048, 64, 8, 4)
        with mesh:
            compiled = jax.jit(
                fn.__wrapped__ if hasattr(fn, "__wrapped__") else fn,
                in_shardings=shardings).lower(*args).compile()
        print("sharded scan dry-run OK")
    """)
