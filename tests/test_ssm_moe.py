"""Mamba-2 SSD and MoE layer correctness (beyond the per-arch smoke)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.common import ArchConfig
from repro.models.layers import init_params

RNG = np.random.default_rng(0)


def _ssm_cfg(**kw):
    base = dict(name="t", family="ssm", n_layers=1, d_model=32, n_heads=4,
                d_ff=0, vocab_size=100, ssm_state=16, ssm_expand=2,
                ssm_head_dim=8, ssm_groups=2, ssm_chunk=8, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_ssd_chunked_equals_sequential_recurrence():
    cfg = _ssm_cfg()
    dims = SSM.ssm_dims(cfg)
    B, L, H, hd, N = 2, 24, dims["n_heads"], cfg.ssm_head_dim, cfg.ssm_state
    xh = RNG.normal(size=(B, L, H, hd)).astype(np.float32)
    dt = np.abs(RNG.normal(size=(B, L, H))).astype(np.float32) * 0.5
    A = -np.abs(RNG.normal(size=(H,))).astype(np.float32)
    Bm = RNG.normal(size=(B, L, H, N)).astype(np.float32)
    Cm = RNG.normal(size=(B, L, H, N)).astype(np.float32)
    y, hf = SSM._ssd_chunked(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(A),
                             jnp.asarray(Bm), jnp.asarray(Cm), chunk=8)
    h = np.zeros((B, H, hd, N), np.float32)
    yref = np.zeros((B, L, H, hd), np.float32)
    for t in range(L):
        a = np.exp(dt[:, t] * A[None, :])
        xb = xh[:, t] * dt[:, t][..., None]
        h = h * a[..., None, None] + np.einsum("bhp,bhn->bhpn", xb, Bm[:, t])
        yref[:, t] = np.einsum("bhpn,bhn->bhp", h, Cm[:, t])
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunk_size_invariance(chunk):
    """ssm_chunk is a pure performance knob — outputs must not change
    (the §Perf hymba iteration relies on this)."""
    cfg = _ssm_cfg(ssm_chunk=chunk)
    params = init_params(SSM.ssm_schema(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 16, 32)).astype(np.float32))
    ref_cfg = _ssm_cfg(ssm_chunk=16)
    y = SSM.ssm_apply(params, x, cfg)
    yr = SSM.ssm_apply(params, x, ref_cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


def test_ssm_train_equals_incremental_decode():
    cfg = _ssm_cfg()
    dims = SSM.ssm_dims(cfg)
    params = init_params(SSM.ssm_schema(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    B, L = 2, 12
    x = jnp.asarray(RNG.normal(size=(B, L, cfg.d_model)).astype(np.float32))
    y_train, (conv_f, h_f) = SSM.ssm_apply(params, x, cfg, return_state=True)
    conv = jnp.zeros((B, dims["conv_dim"], cfg.ssm_conv - 1), jnp.float32)
    h = jnp.zeros((B, dims["n_heads"], cfg.ssm_head_dim, cfg.ssm_state),
                  jnp.float32)
    outs = []
    for t in range(L):
        o, conv, h = SSM.ssm_decode_step(params, x[:, t:t + 1], cfg, conv, h)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(y_train),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h), rtol=2e-3,
                               atol=2e-3)


def _moe_cfg(**kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                d_ff=64, vocab_size=100, n_experts=8, moe_top_k=2,
                n_shared_experts=1, moe_d_ff=16, capacity_factor=8.0,
                dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_moe_grouped_equals_dense_reference():
    cfg = _moe_cfg()
    params = init_params(MOE.moe_schema(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 12, 32)).astype(np.float32))
    y1 = MOE.moe_apply(params, x, cfg, mesh=None)
    y2 = MOE.moe_apply(params, x, cfg.replace(moe_impl="dense_tp"), mesh=None)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_moe_capacity_drops_overflow():
    """With capacity_factor << 1 some tokens must be dropped (shared experts
    still serve them) — output differs from the dropless dense path."""
    cfg = _moe_cfg(capacity_factor=0.01, n_shared_experts=0)
    params = init_params(MOE.moe_schema(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(RNG.normal(size=(4, 64, 32)).astype(np.float32))
    y1 = MOE.moe_apply(params, x, cfg, mesh=None)
    y2 = MOE.moe_apply(params, x, cfg.replace(moe_impl="dense_tp"),
                       mesh=None)
    assert not np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)


def test_moe_grads_finite():
    cfg = _moe_cfg()
    params = init_params(MOE.moe_schema(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 8, 32)).astype(np.float32))

    def loss(p):
        return jnp.sum(MOE.moe_apply(p, x, cfg, mesh=None) ** 2)

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
