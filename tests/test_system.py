"""End-to-end behaviour of the paper's system: synthetic WIKI-Dir twin,
all three strategies × {flat, IVF} executors, DSQ quality + DSM consistency +
the OpenViking-style RAG pipeline on top."""
import numpy as np
import pytest

import jax

from repro.configs import smoke_config
from repro.core import STRATEGIES
from repro.datasets import brute_force_ground_truth, make_wiki_dir
from repro.models import model_schema
from repro.models.layers import init_params
from repro.serving.rag import ContextDatabase, RAGConfig, RAGServer
from repro.vectordb import DirectoryVectorDB


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_dir(scale=0.001, dim=32, n_queries=10, seed=11)


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_end_to_end_scoped_retrieval(strategy, wiki):
    db = DirectoryVectorDB(dim=32, scope_strategy=strategy)
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    gt = brute_force_ground_truth(wiki, k=10)
    for qi in range(len(wiki.queries)):
        r = db.dsq(wiki.queries[qi], wiki.query_anchors[qi], k=10,
                   recursive=bool(wiki.query_recursive[qi]))
        want = set(gt[qi][gt[qi] >= 0].tolist())
        got = set(r.ids[0][r.ids[0] >= 0].tolist())
        assert got == want, (strategy, qi)
    db.check_invariants()


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_dsm_workload_preserves_retrieval(strategy, wiki):
    """Apply the MOVE/MERGE workload; scoped retrieval must stay exact w.r.t.
    a freshly-built index over the final layout (strategies agree)."""
    db = DirectoryVectorDB(dim=32, scope_strategy=strategy)
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    applied = []
    for src, dst in wiki.moves[:10] + wiki.merges[:10]:
        kind = "move" if (src, dst) in wiki.moves[:10] else "merge"
        try:
            (db.move if kind == "move" else db.merge)(src, dst)
            applied.append((kind, src, dst))
        except (KeyError, ValueError):
            pass
    assert applied, "no DSM op applied"
    db.check_invariants()
    # rebuild a reference index with the post-DSM entry locations
    ref = DirectoryVectorDB(dim=32, scope_strategy="triehi")
    paths = [
        "/" + "/".join(db.namespaces["fs"].entry_dir(i) or ()) + "/"
        for i in range(wiki.n_entries)]
    paths = [p if p != "//" else "/" for p in paths]
    ref.ingest(wiki.vectors, paths)
    ref.build_ann("flat")
    q = wiki.queries[0]
    for anchor in ["/", wiki.query_anchors[0]]:
        a = db.dsq(q, anchor, k=10)
        b = ref.dsq(q, anchor, k=10)
        assert set(a.ids[0].tolist()) == set(b.ids[0].tolist())


def test_openviking_rag_pipeline(wiki):
    """Tiered context store + scoped retrieval + tiny-LM batched decode."""
    dim = 32
    ctx = ContextDatabase(dim=dim)
    rng = np.random.default_rng(0)
    for i in range(min(wiki.n_entries, 300)):
        tier = ("L0", "L1", "L2")[i % 3]
        toks = rng.integers(0, 200, size=8 + (i % 3) * 8)
        ctx.add_context(wiki.vectors[i], wiki.entry_paths[i], tier, toks)
    ctx.build("flat")
    # context reorganization (agent memory consolidation) = DSM
    dirs = [d for d in ctx.db.namespaces["fs"].list_dirs() if len(d) == 1]
    if len(dirs) >= 2:
        try:
            ctx.reorganize("merge", "/" + dirs[0][0] + "/",
                           "/" + dirs[1][0] + "/")
        except (KeyError, ValueError):
            pass
    ctx.db.check_invariants()

    cfg = smoke_config("qwen3-0.6b").replace(vocab_size=256)
    params = init_params(model_schema(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype())
    server = RAGServer(ctx, params, cfg, RAGConfig(k=5, token_budget=48))
    out = server.answer(
        query_vecs=wiki.queries[:2], scopes=["/", "/"],
        prompts=[np.arange(4, dtype=np.int32)], max_new_tokens=3)
    assert out["tokens"].shape == (2, 3)
    assert all(s["scope_size"] > 0 for s in out["retrieval_stats"])


def test_each_request_gets_its_own_prompt(wiki):
    """Regression: assemble_with_prompt used ``prompts[0]`` for every request
    in the batch; each request must end with its *own* prompt tokens."""
    dim = 32
    ctx = ContextDatabase(dim=dim)
    rng = np.random.default_rng(2)
    for i in range(50):
        ctx.add_context(wiki.vectors[i], wiki.entry_paths[i], "L0",
                        rng.integers(0, 200, size=8))
    ctx.build("flat")
    cfg = smoke_config("qwen3-0.6b").replace(vocab_size=256)
    params = init_params(model_schema(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype())
    server = RAGServer(ctx, params, cfg, RAGConfig(k=3, token_budget=32))
    prompts = [np.full(4, 7, np.int32), np.full(6, 9, np.int32)]
    retrieved = ctx.retrieve_batch(wiki.queries[:2], ["/", "/"], server.cfg)
    for i, (hits, _) in enumerate(retrieved):
        assembled = server.assemble_with_prompt(
            hits, server._prompt_for(prompts, i))
        tail = assembled[-len(prompts[i]):]
        np.testing.assert_array_equal(tail, prompts[i])
    assert len(server._prompt_for(prompts, 1)) == 6
    # broadcast (1 prompt, N requests) and empty still work
    np.testing.assert_array_equal(server._prompt_for([prompts[0]], 1),
                                  prompts[0])
    assert server._prompt_for([], 1).size == 0
    # end-to-end through the batched answer path
    out = server.answer(query_vecs=wiki.queries[:2], scopes=["/", "/"],
                        prompts=prompts, max_new_tokens=2)
    assert out["tokens"].shape == (2, 2)
    with pytest.raises(ValueError):
        server.answer(query_vecs=wiki.queries[:3], scopes=["/", "/", "/"],
                      prompts=prompts, max_new_tokens=1)


def test_tiered_budget_assembly():
    ctx = ContextDatabase(dim=8)
    rng = np.random.default_rng(1)
    for i in range(20):
        ctx.add_context(rng.normal(size=8).astype(np.float32),
                        "/m/", "L2", np.arange(100, dtype=np.int32))
    ctx.build("flat")
    cfg = RAGConfig(k=10, token_budget=64, escalate_top=2)
    hits, _ = ctx.retrieve(np.zeros(8, np.float32), "/m/", cfg)
    toks = ctx.assemble(hits, cfg)
    assert len(toks) <= 64
