"""Optimizer, data determinism, checkpoint/restart, DSM journal recovery."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import DSM, DSMExecutor, DSMJournal, make_scope_index
from repro.models import loss_fn, model_schema
from repro.models.layers import init_params
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticLMData
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


def test_loss_decreases_on_tiny_model():
    cfg = smoke_config("qwen3-0.6b").replace(n_layers=1, d_model=32,
                                             d_ff=64, vocab_size=64,
                                             head_dim=8, n_kv_heads=2)
    params = init_params(model_schema(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype())
    opt_state = init_opt_state(params)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 32, 8))
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3, total_steps=60,
                                                  warmup_steps=5)))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1, (
        losses[:5], losses[-5:])


def test_grad_accum_matches_big_batch():
    cfg = smoke_config("qwen3-0.6b").replace(n_layers=1, d_model=32, d_ff=64,
                                             vocab_size=64, head_dim=8,
                                             n_kv_heads=2)
    params = init_params(model_schema(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype())
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 16, 8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = OptConfig(lr=1e-3)
    s1 = make_train_step(cfg, opt, accum_steps=1)
    s4 = make_train_step(cfg, opt, accum_steps=4)
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p4, _, m4 = s4(params, init_opt_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-4)


def test_data_determinism_and_structure():
    data = SyntheticLMData(DataConfig(vocab_size=128, seq_len=32,
                                      global_batch=4, seed=7))
    b1, b2 = data.batch(5), data.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(data.batch(6)["tokens"], b1["tokens"])
    # labels are next-token-shifted
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_checkpoint_atomicity_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": jnp.arange(8, dtype=jnp.float32),
             "nested": {"b": jnp.ones((2, 3))}}
    for s in (1, 2, 3):
        mgr.save(s, state, extra={"loss": 0.5 * s})
    assert mgr.all_steps() == [2, 3]            # keep=2 GC'd step 1
    # a crashed save (tmp dir, no manifest) must be invisible
    (tmp_path / "step_0000000009.tmp").mkdir()
    (tmp_path / "step_0000000010").mkdir()      # no MANIFEST
    assert mgr.latest_step() == 3
    restored, step, extra = mgr.restore(state)
    assert step == 3 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.zeros(128)}
    mgr.save_async(7, state)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_dsm_journal_recovery(tmp_path):
    jpath = str(tmp_path / "dsm.journal")
    idx = make_scope_index("triehi")
    idx.insert(1, "/a/b/")
    idx.insert(2, "/c/")
    ex = DSMExecutor(idx, DSMJournal(jpath))
    ex.apply(DSM("move", "/a/b/", "/c/"))
    # simulate a crash: write a BEGIN with no COMMIT
    with open(jpath, "a") as f:
        f.write(json.dumps({"event": "begin", "seq": 99, "kind": "merge",
                            "src": "/a/", "dst": "/c/", "ts": 0}) + "\n")
    suspects = DSMJournal.recover(jpath)
    assert len(suspects) == 1
    assert suspects[0].kind == "merge" and suspects[0].src == "/a/"


def test_region_locks_serialize_overlaps():
    from repro.core.ops import RegionLockManager, regions_overlap
    from repro.core import paths as P
    assert regions_overlap([P.parse("/a/")], [P.parse("/a/b/")])
    assert not regions_overlap([P.parse("/a/")], [P.parse("/b/")])
    mgr = RegionLockManager()
    t1 = mgr.acquire([P.parse("/a/")])
    t2 = mgr.acquire([P.parse("/b/")])     # disjoint: no block
    mgr.release(t1)
    mgr.release(t2)


def test_int8_compression_roundtrip_accuracy():
    from repro.training.train_step import int8_psum  # noqa: F401  (API exists)
    # quantization error bound on a single device via the same math
    g = np.random.default_rng(0).normal(size=(1000,)).astype(np.float32)
    scale = np.abs(g).max() / 127.0
    q = np.clip(np.round(g / scale), -127, 127).astype(np.int8)
    rt = q.astype(np.float32) * scale
    assert np.abs(rt - g).max() <= scale * 0.5 + 1e-6
