"""ANN executors + DirectoryVectorDB facade."""
import numpy as np
import pytest

from repro.core import make_scope_index
from repro.datasets import (brute_force_ground_truth, make_arxiv_dir,
                            make_wiki_dir)
from repro.vectordb import (DirectoryVectorDB, FlatExecutor, IVFIndex,
                            PGIndex, VectorStore)


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_dir(scale=0.0015, dim=48, n_queries=12, seed=3)


@pytest.fixture(scope="module")
def db(wiki):
    db = DirectoryVectorDB(dim=48, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    db.build_ann("ivf", n_lists=16)
    db.build_ann("pg", max_degree=10, ef_construction=24)
    return db


def test_flat_is_exact(wiki, db):
    gt = brute_force_ground_truth(wiki, k=10)
    for qi in range(len(wiki.queries)):
        r = db.dsq(wiki.queries[qi], wiki.query_anchors[qi], k=10,
                   recursive=bool(wiki.query_recursive[qi]))
        want = gt[qi][gt[qi] >= 0]
        got = r.ids[0][r.ids[0] >= 0]
        assert set(got.tolist()) == set(want.tolist())


def test_flat_gather_and_scan_plans_agree(db, wiki):
    q = wiki.queries[:4]
    cand = np.arange(0, len(db.store), 3, dtype=np.uint32)
    flat = db.executors["flat"]
    s1, i1 = flat.search(q, 8, candidate_ids=cand, plan="gather")
    s2, i2 = flat.search(q, 8, candidate_ids=cand, plan="scan")
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
    assert set(map(tuple, i1.tolist())) == set(map(tuple, i2.tolist()))


@pytest.mark.parametrize("executor,params,floor", [
    ("ivf", {"nprobe": 12}, 0.6),
    ("pg", {"ef_search": 48}, 0.55),
])
def test_ann_recall_floor(wiki, db, executor, params, floor):
    gt = brute_force_ground_truth(wiki, k=10)
    recalls = []
    for qi in range(len(wiki.queries)):
        r = db.dsq(wiki.queries[qi], wiki.query_anchors[qi], k=10,
                   recursive=bool(wiki.query_recursive[qi]),
                   executor=executor, **params)
        want = set(gt[qi][gt[qi] >= 0].tolist())
        if not want:
            continue
        got = set(r.ids[0][r.ids[0] >= 0].tolist())
        recalls.append(len(got & want) / len(want))
    assert np.mean(recalls) >= floor, np.mean(recalls)


def test_empty_scope_returns_padding(db):
    db.mkdir("/definitely/empty/")
    r = db.dsq(np.zeros(48, np.float32), "/definitely/empty/", k=5)
    assert r.scope_size == 0
    assert (r.ids == -1).all()


def test_dsm_through_facade_keeps_consistency(wiki):
    db = DirectoryVectorDB(dim=48, scope_strategy="triehi")
    db.ingest(wiki.vectors, wiki.entry_paths)
    db.build_ann("flat")
    applied = 0
    for src, dst in wiki.moves[:15]:
        try:
            db.move(src, dst)
            applied += 1
        except (KeyError, ValueError):
            pass
    for src, dst in wiki.merges[:15]:
        try:
            db.merge(src, dst)
            applied += 1
        except (KeyError, ValueError):
            pass
    assert applied > 0
    db.check_invariants()
    # scoped search still exact after restructuring
    r = db.dsq(wiki.queries[0], "/", k=10)
    assert (r.ids[0] >= 0).sum() == 10


def test_multi_namespace_arxiv():
    ds = make_arxiv_dir(scale=0.0005, dim=24, n_queries=4)
    db = DirectoryVectorDB(dim=24)
    db.ingest(ds.vectors, ds.entry_paths, namespaces=ds.extra_namespaces)
    db.build_ann("flat")
    all_subject = db.dsq(ds.queries[0], "/", k=5, namespace="fs")
    all_time = db.dsq(ds.queries[0], "/", k=5, namespace="time")
    assert all_subject.scope_size == all_time.scope_size == ds.n_entries
    # a temporal scope differs from a subject scope
    t_dirs = sorted(db.namespaces["time"].list_dirs())[:5]
    deep = [d for d in t_dirs if d]
    if deep:
        r = db.dsq(ds.queries[0], deep[0], k=5, namespace="time")
        assert r.scope_size < ds.n_entries


def test_store_growth_and_incremental_ivf(wiki):
    db = DirectoryVectorDB(dim=48)
    half = wiki.n_entries // 2
    db.ingest(wiki.vectors[:half], wiki.entry_paths[:half])
    db.build_ann("ivf", n_lists=8)
    db.ingest(wiki.vectors[half:], wiki.entry_paths[half:])
    r = db.dsq(wiki.queries[0], "/", k=10, executor="ivf", nprobe=8)
    assert (r.ids[0] >= 0).sum() == 10
    total = sum(len(lst) for lst in db.executors["ivf"].lists)
    assert total == wiki.n_entries
